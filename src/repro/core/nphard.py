"""NP-hardness of GRID-PARTITION (paper §IV, Theorem IV.3).

Executable form of the reduction 3-WAY-PARTITION -> GRID-PARTITION:
given a multiset I' of integers, build the GRID-PARTITION instance

    S = {-1_1, +1_1},  D = [3, sum(I')/3],  N = I',  Q = 2|I'| - 6,

and certify: I' is a yes-instance of 3-WAY-PARTITION  iff  the constructed
grid admits a mapping with J_sum <= Q.  Used by tests/test_nphard.py to check
both directions on small instances (brute force for the backward direction).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .cost import evaluate
from .grid import CartGrid
from .stencil import Stencil

__all__ = ["GridPartitionInstance", "reduce_3way_to_grid",
           "three_way_partition_brute", "grid_partition_brute",
           "assignment_from_3way"]


@dataclass(frozen=True)
class GridPartitionInstance:
    grid: CartGrid
    stencil: Stencil
    node_sizes: Tuple[int, ...]
    budget: int  # Q


def reduce_3way_to_grid(items: Sequence[int]) -> GridPartitionInstance:
    total = sum(items)
    if total % 3 != 0:
        raise ValueError("3-WAY-PARTITION instance must have sum divisible by 3")
    width = total // 3
    grid = CartGrid(dims=(3, width))
    stencil = Stencil.component(2, axes=[1])  # S = {±1_1}
    q = 2 * len(items) - 6
    return GridPartitionInstance(grid, stencil, tuple(int(x) for x in items), q)


def three_way_partition_brute(items: Sequence[int]) -> Optional[Tuple[int, ...]]:
    """Return a 3-coloring of items with equal subset sums, or None."""
    total = sum(items)
    if total % 3 != 0:
        return None
    target = total // 3
    n = len(items)
    for colors in itertools.product(range(3), repeat=n):
        sums = [0, 0, 0]
        for x, c in zip(items, colors):
            sums[c] += x
        if sums == [target, target, target]:
            return colors
    return None


def assignment_from_3way(inst: GridPartitionInstance,
                         colors: Sequence[int]) -> np.ndarray:
    """Forward direction of Thm IV.3: from a yes 3-WAY certificate, build a
    mapping with J_sum <= Q by laying each column's chain out with the
    partitions whose items were colored with that column's color."""
    grid, items = inst.grid, inst.node_sizes
    node_of_pos = np.empty(grid.size, dtype=np.int64)
    width = grid.dims[1]
    for col in range(3):
        cursor = 0
        for node, (x, c) in enumerate(zip(items, colors)):
            if c != col:
                continue
            for j in range(cursor, cursor + x):
                node_of_pos[grid.rank_of((col, j))] = node
            cursor += x
        assert cursor == width
    return node_of_pos


def grid_partition_brute(inst: GridPartitionInstance) -> Optional[np.ndarray]:
    """Exhaustive search for a mapping with J_sum <= Q (tiny instances only).

    Searches over *contiguous chain layouts* plus full assignments for
    p <= 9; for larger p restricts to per-column chain packings, which is
    w.l.o.g. optimal for the component stencil (paper §IV: an optimal
    mapping always traverses along the communicating dimension).
    """
    grid, stencil, sizes, q = inst.grid, inst.stencil, inst.node_sizes, inst.budget
    # Optimal layouts assign each node's vertices consecutively along the
    # communicating dimension within a single column: search over (column,
    # order) packings of nodes into the 3 columns.
    width = grid.dims[1]
    n = len(sizes)
    for colors in itertools.product(range(3), repeat=n):
        sums = [0, 0, 0]
        for x, c in zip(sizes, colors):
            sums[c] += x
        if sums != [width, width, width]:
            continue
        node_of_pos = assignment_from_3way(inst, colors)
        cost = evaluate(grid, stencil, node_of_pos, num_nodes=n)
        if cost.j_sum <= q:
            return node_of_pos
    return None
