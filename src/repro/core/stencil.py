"""k-neighborhood stencils (paper §II, "Target Stencils").

A stencil is a list of relative coordinate vectors
``S = {R_0 .. R_{k-1}}``; process at grid coordinate ``c`` communicates with
``c + R_i`` for every ``i``.  We extend the paper's unit-weight edges with an
optional per-offset byte weight (used by the mesh builder to encode how much
traffic each mesh axis carries; weight 1.0 everywhere reproduces the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence, Tuple

import numpy as np

__all__ = ["Stencil", "resolve_weighted"]


def _unit(d: int, i: int, a: int = 1) -> Tuple[int, ...]:
    v = [0] * d
    v[i] = a
    return tuple(v)


@dataclass(frozen=True)
class Stencil:
    """A k-neighborhood: offsets (k, d) plus optional per-offset weights."""

    offsets: Tuple[Tuple[int, ...], ...]
    weights: Tuple[float, ...] = None  # type: ignore[assignment]
    name: str = "custom"

    def __post_init__(self):
        offs = tuple(tuple(int(x) for x in o) for o in self.offsets)
        if not offs:
            raise ValueError("stencil must have at least one offset")
        d = len(offs[0])
        if any(len(o) != d for o in offs):
            raise ValueError("all offsets must have the same rank")
        if any(all(x == 0 for x in o) for o in offs):
            raise ValueError("zero offset (self-communication) not allowed")
        if len(set(offs)) != len(offs):
            raise ValueError(f"duplicate offsets in stencil: {offs}")
        object.__setattr__(self, "offsets", offs)
        w = self.weights
        if w is None:
            w = (1.0,) * len(offs)
        w = tuple(float(x) for x in w)
        if len(w) != len(offs) or any(x <= 0 for x in w):
            raise ValueError("weights must be positive, one per offset")
        object.__setattr__(self, "weights", w)

    # -- constructors for the paper's three stencils ------------------------
    @staticmethod
    def nearest_neighbor(d: int) -> "Stencil":
        """(a): S = {±1_i | 0 <= i < d}."""
        offs = [_unit(d, i, s) for i in range(d) for s in (+1, -1)]
        return Stencil(tuple(offs), name="nearest_neighbor")

    @staticmethod
    def component(d: int, axes: Sequence[int] | None = None) -> "Stencil":
        """(b): S = {±1_i | 0 <= i < d-1} (or explicit ``axes``)."""
        if axes is None:
            axes = range(d - 1) if d > 1 else range(d)
        offs = [_unit(d, i, s) for i in axes for s in (+1, -1)]
        return Stencil(tuple(offs), name="component")

    @staticmethod
    def nn_with_hops(d: int, hops: Sequence[int] = (2, 3), axis: int = 0) -> "Stencil":
        """(c): nearest neighbor plus ±a·1_axis for a in hops."""
        offs = [_unit(d, i, s) for i in range(d) for s in (+1, -1)]
        offs += [_unit(d, axis, s * a) for a in hops for s in (+1, -1)]
        return Stencil(tuple(offs), name="nn_with_hops")

    @staticmethod
    def from_flat(flat: Sequence[int], ndims: int, k: int,
                  weights: Sequence[float] | None = None) -> "Stencil":
        """The paper's ``MPIX_Cart_stencil_comm`` interface: ``stencil[]`` is a
        flattened list of k relative offsets of length ndims each."""
        flat = list(flat)
        if len(flat) != ndims * k:
            raise ValueError(f"flat stencil length {len(flat)} != ndims*k = {ndims * k}")
        offs = tuple(tuple(flat[i * ndims:(i + 1) * ndims]) for i in range(k))
        return Stencil(offs, tuple(weights) if weights is not None else None,
                       name="flat")

    # -- derived quantities used by the algorithms --------------------------
    @property
    def k(self) -> int:
        return len(self.offsets)

    @property
    def is_weighted(self) -> bool:
        """True if any offset carries a non-unit byte weight.  The refine
        stack's ``weighted="auto"`` mode keys off this, so byte-weighted
        stencils (``launch.mesh.stencil_for_plan``) are optimized in bytes
        and unit stencils in edge counts through one code path."""
        return any(w != 1.0 for w in self.weights)

    @property
    def ndim(self) -> int:
        return len(self.offsets[0])

    def array(self) -> np.ndarray:
        return np.asarray(self.offsets, dtype=np.int64)

    def weight_array(self) -> np.ndarray:
        return np.asarray(self.weights, dtype=np.float64)

    def cos2_sums(self, weighted: bool = False) -> np.ndarray:
        """Eq. (2): per-dimension sum over offsets of cos^2(angle(R, e_j)).

        Low value == dimension most orthogonal to the stencil == preferred
        cut dimension for the Hyperplane algorithm.

        ``weighted=True`` is our beyond-paper extension: each offset's
        contribution is scaled by its byte weight, so a cut avoids the
        *heaviest* traffic, not just the most edges (needed when mesh axes
        carry asymmetric collective volumes — TP bytes >> DP bytes).
        """
        R = self.array().astype(np.float64)
        norms2 = np.sum(R * R, axis=1)
        w = self.weight_array() if weighted else np.ones(self.k)
        w = w / w.mean()
        # cos^2(R, e_j) = R_j^2 / |R|^2  (|e_j| = 1)
        return np.sum(w[:, None] * (R * R) / norms2[:, None], axis=0)

    def axis_comm_counts(self, weighted: bool = False) -> np.ndarray:
        """k-d tree's f_j = |{R in S : R_j != 0}| per dimension
        (``weighted=True``: sum of byte weights instead of the count)."""
        nz = self.array() != 0
        if weighted:
            return (nz * self.weight_array()[:, None]).sum(axis=0)
        return np.count_nonzero(nz, axis=0).astype(np.int64)

    def extents(self) -> np.ndarray:
        """Stencil Strips' e_i = max R_i - min R_i per dimension."""
        R = self.array()
        return (R.max(axis=0) - R.min(axis=0)).astype(np.int64)

    def distortion_factors(self) -> np.ndarray:
        """Stencil Strips' alpha_i = e_i / V_b^(1/d_b) (paper §V.C).

        V_b uses eps_i = max(e_i, 1); the numerator keeps the paper's raw
        e_i, so dimensions with no communication get alpha_i = 0 — their
        strip length clamps to 1 (thinnest strips across silent dimensions),
        which is what makes Stencil Strips optimal on the component stencil
        (paper §VI.D).
        """
        e = self.extents().astype(np.float64)
        eps = np.where(e == 0, 1.0, e)
        d_b = int(np.count_nonzero(e))
        if d_b == 0:
            return np.ones_like(eps)
        v_b = float(np.prod(eps))
        return e / (v_b ** (1.0 / d_b))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Stencil({self.name}, k={self.k}, d={self.ndim})"


def resolve_weighted(weighted, stencil: Stencil) -> bool:
    """Resolve a ``weighted`` argument (True / False / ``"auto"``) against a
    stencil.  ``"auto"`` means: use the stencil's per-offset byte weights
    exactly when it carries non-unit ones — the mode the refine stack
    defaults to, so mapping quality follows bytes whenever the caller's
    stencil encodes them (``stencil_for_plan``) and reproduces the paper's
    unit-edge objective otherwise."""
    if weighted == "auto":
        return stencil.is_weighted
    return bool(weighted)
