"""GraphGreedy — general graph-mapping baseline (VieM stand-in, paper §III).

The paper compares against VieM (Vienna Mapping, Schulz & Träff), an external
sequential C++ tool doing multilevel partitioning + randomized local search on
the *explicit* communication graph.  We reproduce that role natively:

  1. greedy graph-growing partitioning (GGG): grow each node's partition by
     repeatedly absorbing the unassigned vertex with maximal gain (number of
     weighted edges into the partition), seeded at the boundary of the
     previous region;
  2. randomized pairwise-swap local search over connected vertex pairs in
     different partitions (the paper's strongest VieM setting), first-improve,
     until a pass yields no improvement or ``max_passes`` is hit.

Intentionally general and slow — it plays VieM's part in the runtime
comparison (Fig. 9) and the quality comparison (Fig. 8).

Because it only ever walks ``shift_ranks`` adjacency, it is also the
natural base for arbitrary sparse graphs: under the ``graph:`` plan
flavor it runs on a :class:`~repro.core.graph.CommGraph`'s slot
decomposition unchanged, and ``annealed:graphgreedy`` is the default
graph plan (:data:`~repro.core.plan.DEFAULT_GRAPH_PLAN`).  Bracket
options configure it by name — ``graphgreedy[seed=3,max_passes=2]`` —
with a canonical plan key (``graphgreedy{max_passes=2,seed=3}``).
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from ..cost import node_of_rank_blocked
from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper

__all__ = ["GraphGreedyMapper"]


def _build_graph(grid: CartGrid, stencil: Stencil
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Directed edge list (src, dst, weight) over grid positions."""
    srcs, dsts, ws = [], [], []
    for off, w in zip(stencil.offsets, stencil.weights):
        valid, tgt = grid.shift_ranks(off)
        idx = np.nonzero(valid)[0]
        srcs.append(idx)
        dsts.append(tgt[idx])
        ws.append(np.full(len(idx), w))
    return (np.concatenate(srcs), np.concatenate(dsts), np.concatenate(ws))


def _csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst, w


class GraphGreedyMapper(Mapper):
    name = "graphgreedy"

    def __init__(self, seed: int = 0, max_passes: int = 10):
        self.seed = int(seed)
        self.max_passes = int(max_passes)

    # The general tool assigns grid positions to nodes directly; the
    # rank->coordinate form is recovered afterwards so the Mapper contract
    # (bijection + blocked ownership) still holds.
    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        part = self._partition(grid, stencil, node_sizes)
        # positions of node i, in row-major order, are given to node i's ranks
        sizes = np.asarray(node_sizes, dtype=np.int64)
        owner_of_rank = node_of_rank_blocked(sizes)
        pos_of_rank = np.empty(grid.size, dtype=np.int64)
        next_slot = np.zeros(len(sizes), dtype=np.int64)
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        for pos in range(grid.size):
            nd = part[pos]
            pos_of_rank[starts[nd] + next_slot[nd]] = pos
            next_slot[nd] += 1
        return np.stack(np.unravel_index(pos_of_rank, grid.dims), axis=1)

    def _partition(self, grid: CartGrid, stencil: Stencil,
                   node_sizes: Sequence[int]) -> np.ndarray:
        p = grid.size
        rng = np.random.default_rng(self.seed)
        src, dst, w = _build_graph(grid, stencil)
        indptr, nbr, ew = _csr(p, src, dst, w)
        part = np.full(p, -1, dtype=np.int64)

        # --- phase 1: greedy graph growing -------------------------------
        # Gain = weighted edges into the growing region; ties broken by BFS
        # distance from the region seed (keeps regions round instead of
        # degenerating into row-major stripes), then by index.
        gain = np.zeros(p, dtype=np.float64)
        unassigned = p

        def bfs_dist(seed: int) -> np.ndarray:
            dist = np.full(p, np.inf)
            dist[seed] = 0
            frontier = [seed]
            d = 0
            while frontier:
                nxt = []
                for v in frontier:
                    for e in range(indptr[v], indptr[v + 1]):
                        u = int(nbr[e])
                        if part[u] == -1 and dist[u] == np.inf:
                            dist[u] = d + 1
                            nxt.append(u)
                frontier = nxt
                d += 1
            return dist

        for node, size in enumerate(node_sizes):
            if unassigned == p:
                seed_v = 0
            else:
                cand = np.nonzero(part == -1)[0]
                seed_v = int(cand[np.argmax(gain[cand])])
            dist = bfs_dist(seed_v)
            grown = 0
            region_gain = np.zeros(p, dtype=np.float64)
            v = seed_v
            while grown < size:
                part[v] = node
                unassigned -= 1
                grown += 1
                for e in range(indptr[v], indptr[v + 1]):
                    u = nbr[e]
                    if part[u] == -1:
                        region_gain[u] += ew[e]
                        gain[u] += ew[e]
                if grown == size:
                    break
                cand = np.nonzero((part == -1) & (region_gain > 0))[0]
                if len(cand) == 0:
                    cand = np.nonzero(part == -1)[0]
                    v = int(cand[0])
                else:
                    # lexicographic: max gain, then min BFS distance, then idx
                    g = region_gain[cand]
                    best = cand[g == g.max()]
                    dd = dist[best]
                    best = best[dd == dd.min()]
                    v = int(best[0])
        assert unassigned == 0

        # --- phase 2: randomized pairwise-swap local search ---------------
        def vertex_cost(v: int, pt: np.ndarray) -> float:
            c = 0.0
            for e in range(indptr[v], indptr[v + 1]):
                if pt[nbr[e]] != pt[v]:
                    c += ew[e]
            return c

        edges = np.stack([src, dst], axis=1)
        for _ in range(self.max_passes):
            improved = False
            cross = edges[part[edges[:, 0]] != part[edges[:, 1]]]
            if len(cross) == 0:
                break
            order = rng.permutation(len(cross))
            for ei in order:
                u, v = int(cross[ei, 0]), int(cross[ei, 1])
                pu, pv = part[u], part[v]
                if pu == pv:
                    continue
                # delta of swapping u<->v; count both edge directions by
                # evaluating outgoing cost of u, v and their neighbours' edges
                # toward u, v — with symmetric stencils outgoing*2 suffices,
                # but we recompute exactly for generality.
                touched = {u, v}
                for x in (u, v):
                    touched.update(int(nbr[e]) for e in range(indptr[x], indptr[x + 1]))
                before = sum(vertex_cost(x, part) for x in touched)
                part[u], part[v] = pv, pu
                after = sum(vertex_cost(x, part) for x in touched)
                if after < before - 1e-12:
                    improved = True
                else:
                    part[u], part[v] = pu, pv
            if not improved:
                break
        return part
