"""Stencil Strips algorithm (paper §V.C, Algorithm 3).

Partition the grid into *strips* (tubes running along the largest dimension)
whose cross-section extents are close to the scaled edge lengths of the
stencil's optimal bounding rectangle.  For each non-largest dimension ``i``
(processed in ascending index order), the strip length is

    s_i = (alpha_i * n / prod_{j processed earlier} s_j) ** (1 / (d - pos_i))

with ``alpha_i`` the distortion factor of the stencil bounding box (paper's
definition; see :meth:`Stencil.distortion_factors`).  Along dimension ``i`` we
fit ``floor(d_i / s_i)`` strips, the last one absorbing the remainder
(``s_i + d_i mod s_i``).  Ranks fill tube after tube; tubes are visited in
boustrophedon (serpentine) order over the coarse strip grid — and the walk
*along* the largest dimension alternates direction too — so consecutive node
partitions stay spatially cohesive (paper Fig. 5).

The paper reports O(kd) per-rank arithmetic assuming divisible strip counts;
our reference implementation enumerates the full permutation in O(p·d) (we
need the whole permutation for evaluation and mesh construction anyway) and
keeps exact fidelity for remainder strips.  The per-rank closed form for the
evenly-divisible case is `coord_of_rank`.
"""
from __future__ import annotations

import math
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper, aggregate_node_size

__all__ = ["StencilStripsMapper", "strip_lengths", "serpentine_indices"]


def strip_lengths(dims: Sequence[int], stencil: Stencil, n: int
                  ) -> Tuple[int, List[int]]:
    """Return (largest dim index m, strip length s_i per dim; s_m = 1)."""
    d = len(dims)
    alpha = stencil.distortion_factors()
    m = int(np.argmax(dims))
    s = [1] * d
    prod_prev = 1.0
    others = [i for i in range(d) if i != m]
    for pos, i in enumerate(others):
        expo = 1.0 / (d - pos)
        val = (alpha[i] * n / prod_prev) ** expo
        s[i] = int(min(dims[i], max(1, round(val))))
        prod_prev *= s[i]
    return m, s


def serpentine_indices(shape: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    """Boustrophedon enumeration of a multi-index: digit j is reversed iff
    the sum of the (already reversed) higher-significance digits is odd.
    Consecutive indices always differ by ±1 in exactly one coordinate."""
    shape = tuple(int(x) for x in shape)
    if not shape:
        yield ()
        return
    total = math.prod(shape)
    for t in range(total):
        digits = np.unravel_index(t, shape)
        out = []
        parity = 0
        for j, dj in enumerate(digits):
            dj = int(dj)
            if parity % 2 == 1:
                dj = shape[j] - 1 - dj
            out.append(dj)
            parity += dj
        yield tuple(out)


def _strip_ranges(extent: int, s: int) -> List[Tuple[int, int]]:
    """[(start, size)] of strips along one dimension: floor(extent/s) strips,
    the last absorbing the remainder."""
    num = max(1, extent // s)
    ranges = [(i * s, s) for i in range(num)]
    start, size = ranges[-1]
    ranges[-1] = (start, extent - start)
    return ranges


class StencilStripsMapper(Mapper):
    name = "stencil_strips"

    def __init__(self, aggregate: str = "mean"):
        self.aggregate = aggregate

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        n = aggregate_node_size(node_sizes, self.aggregate)
        dims = grid.dims
        d = grid.ndim
        if d == 1:
            return grid.coords()
        m, s = strip_lengths(dims, stencil, n)
        others = [i for i in range(d) if i != m]
        ranges_per_dim = {i: _strip_ranges(dims[i], s[i]) for i in others}
        strip_grid = [len(ranges_per_dim[i]) for i in others]

        out = np.empty((grid.size, d), dtype=np.int64)
        r = 0
        parity_along_m = 0
        for tube_idx in serpentine_indices(strip_grid):
            # cell ranges of this tube's cross-section
            ranges = [ranges_per_dim[i][tube_idx[pos]]
                      for pos, i in enumerate(others)]
            cross_shape = [size for (_, size) in ranges]
            cross_cells = list(np.ndindex(*cross_shape)) if cross_shape else [()]
            layers = range(dims[m])
            if parity_along_m % 2 == 1:
                layers = range(dims[m] - 1, -1, -1)
            for layer in layers:
                for cell in cross_cells:
                    coord = [0] * d
                    coord[m] = layer
                    for pos, i in enumerate(others):
                        coord[i] = ranges[pos][0] + cell[pos]
                    out[r] = coord
                    r += 1
            parity_along_m += 1
        assert r == grid.size
        return out

    @staticmethod
    def coord_of_rank(dims: Sequence[int], stencil: Stencil, n: int, r: int
                      ) -> Tuple[int, ...]:
        """O(d) closed form, valid when every s_i divides d_i (no remainder
        strips).  Used by the distributed-runtime path and in tests."""
        d = len(dims)
        if d == 1:
            return (int(r),)
        m, s = strip_lengths(dims, stencil, n)
        others = [i for i in range(d) if i != m]
        for i in others:
            if dims[i] % s[i] != 0:
                raise ValueError("closed form needs s_i | d_i; use coords()")
        strip_grid = [dims[i] // s[i] for i in others]
        cross = math.prod(s[i] for i in others)
        tube_cells = cross * dims[m]
        tube_rank, in_tube = divmod(int(r), tube_cells)
        # serpentine digits of the tube
        digits = np.unravel_index(tube_rank, tuple(strip_grid))
        tube_coord = []
        parity = 0
        for j, dj in enumerate(digits):
            dj = int(dj)
            if parity % 2 == 1:
                dj = strip_grid[j] - 1 - dj
            tube_coord.append(dj)
            parity += dj
        layer, in_layer = divmod(in_tube, cross)
        if tube_rank % 2 == 1:  # alternate walk direction along m
            layer = dims[m] - 1 - layer
        cell = np.unravel_index(in_layer, tuple(s[i] for i in others))
        coord = [0] * d
        coord[m] = layer
        for pos, i in enumerate(others):
            coord[i] = tube_coord[pos] * s[i] + int(cell[pos])
        return tuple(coord)
