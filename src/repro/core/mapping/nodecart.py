"""Nodecart — Gropp's node-aware Cartesian mapping (paper §III, [9]).

Decomposes the grid dimensions into a node grid and a within-node block via a
prime factorization of the (homogeneous) node size ``n``: find per-dimension
block extents ``c_i`` with ``prod(c) = n`` and ``c_i | d_i``; rank ``r`` is
then placed at ``node_coord * c + local_coord``.

Among all feasible factor assignments we pick the block minimizing its
surface area ``sum_i n / c_i`` (fewest inter-node faces for the implied
nearest-neighbor stencil — Nodecart is stencil-oblivious, which is exactly
the weakness the paper's algorithms address).

Raises :class:`MapperInapplicable` when node sizes are heterogeneous, when
``n`` does not divide ``p``, or when no divisibility-respecting factor
assignment exists.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper, MapperInapplicable

__all__ = ["NodecartMapper", "prime_factors", "find_block_dims"]


def prime_factors(n: int) -> list[int]:
    out = []
    f = 2
    while f * f <= n:
        while n % f == 0:
            out.append(f)
            n //= f
        f += 1
    if n > 1:
        out.append(n)
    return out


def find_block_dims(dims: Sequence[int], n: int) -> Tuple[int, ...]:
    """Exhaustive (tiny) search over prime->dimension assignments."""
    dims = tuple(int(d) for d in dims)
    primes = sorted(prime_factors(n), reverse=True)
    best: Tuple[float, Tuple[int, ...]] | None = None

    def rec(idx: int, c: list[int]):
        nonlocal best
        if idx == len(primes):
            surface = sum(n // ci for ci in c)
            key = (surface, tuple(-x for x in sorted(c)))  # deterministic tie-break
            if best is None or key < best[0]:
                best = (key, tuple(c))
            return
        f = primes[idx]
        tried = set()
        for i in range(len(dims)):
            nc = c[i] * f
            if dims[i] % nc != 0 or nc in tried:
                continue
            tried.add(nc)
            c[i] = nc
            rec(idx + 1, c)
            c[i] //= f

    rec(0, [1] * len(dims))
    if best is None:
        raise MapperInapplicable(
            f"Nodecart: no factorization of n={n} divides dims={dims}")
    return best[1]


class NodecartMapper(Mapper):
    name = "nodecart"
    requires_homogeneous = True

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        sizes = np.asarray(node_sizes, dtype=np.int64)
        if len(np.unique(sizes)) != 1:
            raise MapperInapplicable("Nodecart requires homogeneous node sizes")
        n = int(sizes[0])
        p = grid.size
        if p % n != 0:
            raise MapperInapplicable(f"Nodecart: n={n} does not divide p={p}")
        c = np.asarray(find_block_dims(grid.dims, n), dtype=np.int64)
        node_grid = np.asarray(grid.dims, dtype=np.int64) // c
        r = np.arange(p)
        node_id, local = r // n, r % n
        node_coord = np.stack(np.unravel_index(node_id, tuple(node_grid)), axis=1)
        local_coord = np.stack(np.unravel_index(local, tuple(c)), axis=1)
        return node_coord * c[None, :] + local_coord

    @staticmethod
    def coord_of_rank(dims, stencil, n, r) -> Tuple[int, ...]:
        c = find_block_dims(dims, n)
        node_grid = tuple(d // ci for d, ci in zip(dims, c))
        node_coord = np.unravel_index(r // n, node_grid)
        local_coord = np.unravel_index(r % n, c)
        return tuple(int(nc * ci + lc) for nc, ci, lc in
                     zip(node_coord, c, local_coord))
