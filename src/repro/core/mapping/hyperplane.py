"""Hyperplane algorithm (paper §V.A, Algorithm 1).

Recursive bisection of the grid.  Each step cuts one dimension ``d_i`` into
``d_i' + d_i''`` such that both induced sub-grid sizes are multiples of the
node size ``n``.  The cut dimension is chosen by Eq. (2): the dimension most
orthogonal to the stencil vectors (minimal sum of squared cosines), ties
broken towards the *larger* dimension.  The hyperplane starts at the center
of the candidate dimension and moves outward until a suitable split is found
(Thm V.1 guarantees one exists when p = C*n; Thm V.2 bounds the imbalance by
|g'|/|g''| >= 1/2).

The recursion stops when the grid holds <= 2n vertices; the base case places
ranks directly in "preferred dimension order" (most orthogonal dimension
slowest-varying), which avoids degenerate cuts of skewed grids (the paper's
[2, n] example).

Fully distributed: ``coord_of_rank`` needs only (D, S, n, r) and runs in
O(log N * sum_i d_i).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper, aggregate_node_size

__all__ = ["HyperplaneMapper"]


def _preference_order(dims: Sequence[int], cos2: np.ndarray) -> List[int]:
    """Dimensions sorted by (ascending cos^2 sum, descending size, index)."""
    return sorted(range(len(dims)), key=lambda i: (cos2[i], -dims[i], i))


def _find_split(dims: Sequence[int], cos2: np.ndarray, n: int
                ) -> Optional[Tuple[int, int]]:
    """Return (dim index, left extent d') of the best suitable split.

    Tries candidate dimensions in preference order; within a dimension,
    positions from the center outward (left-biased so |g'| <= |g''|).
    Suitable means both induced sizes are multiples of n.
    """
    total = math.prod(dims)
    for i in _preference_order(dims, cos2):
        d_i = dims[i]
        if d_i < 2:
            continue
        rest = total // d_i
        center = d_i // 2
        for delta in range(0, d_i):
            for h in (center - delta, center + delta):
                if delta == 0 and h != center:
                    continue
                if 1 <= h <= d_i - 1 and (h * rest) % n == 0:
                    return i, h
    return None


def _base_coordinate(dims: Sequence[int], cos2: np.ndarray, rank: int
                     ) -> List[int]:
    """Direct placement for grids <= 2n: mixed-radix decomposition of the
    rank with the *preferred* dimension as the most significant digit."""
    order = _preference_order(dims, cos2)
    coord = [0] * len(dims)
    rem = rank
    for ax in reversed(order):
        coord[ax] = rem % dims[ax]
        rem //= dims[ax]
    return coord


class HyperplaneMapper(Mapper):
    name = "hyperplane"

    def __init__(self, aggregate: str = "mean", weighted: bool = False):
        self.aggregate = aggregate
        self.weighted = weighted  # byte-weighted Eq.(2) (beyond-paper)

    @staticmethod
    def coord_of_rank(dims: Sequence[int], stencil: Stencil, n: int, r: int
                      ) -> Tuple[int, ...]:
        cos2 = stencil.cos2_sums()
        D = list(int(d) for d in dims)
        origin = [0] * len(D)
        rank = int(r)
        while math.prod(D) > 2 * n:
            split = _find_split(D, cos2, n)
            if split is None:
                # p not a multiple of n (heterogeneous input): fall back to a
                # center cut of the most preferred splittable dimension.
                i = next(j for j in _preference_order(D, cos2) if D[j] >= 2)
                split = (i, D[i] // 2)
            i, d_left = split
            left_size = d_left * (math.prod(D) // D[i])
            if rank < left_size:
                D[i] = d_left
            else:
                rank -= left_size
                origin[i] += d_left
                D[i] = D[i] - d_left
        base = _base_coordinate(D, cos2, rank)
        return tuple(o + b for o, b in zip(origin, base))

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        """Batch form: the recursion is identical for every rank inside a
        sub-grid's rank range, so we traverse the bisection tree once
        (O(N) nodes) and fill base-case ranges vectorized — orders of
        magnitude faster than per-rank recursion, bit-identical to it."""
        n = aggregate_node_size(node_sizes, self.aggregate)
        cos2 = stencil.cos2_sums(weighted=self.weighted)
        out = np.empty((grid.size, grid.ndim), dtype=np.int64)
        base_cache: dict = {}  # leaf dims repeat; memoize their templates
        stack = [(list(grid.dims), [0] * grid.ndim, 0, grid.size)]
        while stack:
            D, origin, lo, hi = stack.pop()
            if math.prod(D) <= 2 * n:
                key = tuple(D)
                coords = base_cache.get(key)
                if coords is None:
                    order = _preference_order(D, cos2)
                    rem = np.arange(hi - lo)
                    coords = np.empty((hi - lo, len(D)), dtype=np.int64)
                    for ax in reversed(order):
                        coords[:, ax] = rem % D[ax]
                        rem //= D[ax]
                    base_cache[key] = coords
                out[lo:hi] = coords + np.asarray(origin)[None, :]
                continue
            split = _find_split(D, cos2, n)
            if split is None:
                i = next(j for j in _preference_order(D, cos2) if D[j] >= 2)
                split = (i, D[i] // 2)
            i, d_left = split
            left_size = d_left * (math.prod(D) // D[i])
            Dl, Dr = list(D), list(D)
            Dl[i] = d_left
            Dr[i] = D[i] - d_left
            origin_r = list(origin)
            origin_r[i] += d_left
            stack.append((Dl, list(origin), lo, lo + left_size))
            stack.append((Dr, origin_r, lo + left_size, hi))
        return out
