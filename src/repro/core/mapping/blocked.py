"""Blocked (identity) mapping — the MPI default the paper compares against."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper

__all__ = ["BlockedMapper"]


class BlockedMapper(Mapper):
    name = "blocked"

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        return grid.coords()

    @staticmethod
    def coord_of_rank(dims, stencil, n, r):
        return tuple(int(c) for c in np.unravel_index(r, tuple(dims)))
