"""k-d tree algorithm (paper §V.B, Algorithm 2).

Recursively halves the grid down to single vertices.  The split dimension is
``argmax_i d_i / f_i`` where ``f_i = |{R in S : R_i != 0}|`` is the amount of
communication crossing dimension ``i`` — i.e. prefer cutting long dimensions
that carry little traffic.  Dimensions with no communication at all
(``f_i = 0``) are always cut first (ratio = +inf), which is what lets the
k-d tree find *optimal* mappings for the component stencil (paper §VI.D).

Oblivious to the node size n: it only produces a locality-dense rank order;
blocked node ownership does the rest.  Runtime O(log p * d) per rank.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper

__all__ = ["KDTreeMapper"]


def _split_dim(dims: Sequence[int], f: np.ndarray) -> int:
    """argmax d_i / f_i over splittable dims; f_i = 0 counts as infinity.
    Ties broken toward the larger dimension, then the lower index."""
    best = None
    for i, d in enumerate(dims):
        if d < 2:
            continue
        ratio = math.inf if f[i] == 0 else d / float(f[i])
        key = (ratio, d, -i)
        if best is None or key > best[0]:
            best = (key, i)
    assert best is not None, "no splittable dimension in non-trivial grid"
    return best[1]


class KDTreeMapper(Mapper):
    name = "kdtree"

    def __init__(self, weighted: bool = False):
        self.weighted = weighted  # byte-weighted f_j (beyond-paper)

    @staticmethod
    def coord_of_rank(dims: Sequence[int], stencil: Stencil, n: int, r: int
                      ) -> Tuple[int, ...]:
        """n is accepted for interface uniformity but ignored (§V.B)."""
        f = stencil.axis_comm_counts()
        D = list(int(d) for d in dims)
        origin = [0] * len(D)
        rank = int(r)
        while math.prod(D) > 1:
            k = _split_dim(D, f)
            d_left = D[k] // 2
            left_size = d_left * (math.prod(D) // D[k])
            if rank < left_size:
                D[k] = d_left
            else:
                rank -= left_size
                origin[k] += d_left
                D[k] = D[k] - d_left
        return tuple(origin)

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        """Batch form with memoized sub-grid templates: repeated halving
        produces only O(prod_i log d_i) distinct sub-grid shapes, each of
        which maps its rank range to local coordinates identically — so we
        build each shape's template once and concatenate (bit-identical to
        the per-rank recursion, near-numpy speed)."""
        f = stencil.axis_comm_counts(weighted=self.weighted)
        cache: dict = {}

        def template(D: tuple) -> np.ndarray:
            hit = cache.get(D)
            if hit is not None:
                return hit
            if math.prod(D) == 1:
                out = np.zeros((1, len(D)), dtype=np.int64)
            else:
                k = _split_dim(D, f)
                d_left = D[k] // 2
                Dl = D[:k] + (d_left,) + D[k + 1:]
                Dr = D[:k] + (D[k] - d_left,) + D[k + 1:]
                right = template(Dr).copy()
                right[:, k] += d_left
                out = np.concatenate([template(Dl), right], axis=0)
            cache[D] = out
            return out

        return template(tuple(grid.dims))
