"""Process-to-node mapping algorithms (paper §V + baselines §III).

Mapper-name resolution contract
-------------------------------

:func:`get_mapper` turns a string into a ready :class:`Mapper` instance.
Names resolve in two layers:

1. **Base algorithms** — exact keys of :data:`MAPPERS` (``"blocked"``,
   ``"random"``, ``"nodecart"``, ``"hyperplane"``, ``"kdtree"``,
   ``"stencil_strips"``, ``"graphgreedy"``).  ``kwargs`` go to the
   algorithm's constructor.  Bases take bracket options too —
   ``"graphgreedy[seed=3,max_passes=2]"`` — same ``key=value`` syntax
   and coercion as refinement prefixes, merged over ``kwargs`` (bracket
   wins), rendered canonically in the plan key
   (``graphgreedy{max_passes=2,seed=3}``).
2. **Refinement prefixes** — ``"<prefix>[<options>]:<base>"`` recursively
   resolves ``<base>`` (so a base's own name rules apply unchanged) and
   wraps it in a :class:`~repro.core.refine.RefinedMapper`.  Refiner
   configuration comes from the optional *bracket options* — a
   comma-separated ``key=value`` list, e.g. ``"portfolio[k=8,seed=3]:"``,
   with values coerced ``int`` → ``float`` → ``bool`` → ``str`` — merged
   over any ``kwargs`` (bracket options win; the spelled name is the more
   specific spec).  Either way they configure the *refiner*, never the
   base algorithm:

   ============ ===================================================== =========
   prefix       refiner                                               objective
   ============ ===================================================== =========
   refined:     :class:`~repro.core.refine.SwapRefiner`               J_sum
   refined2:    :class:`~repro.core.refine.ScheduledRefiner`          (J_max, J_sum)
   annealed:    ScheduledRefiner(anneal=True) — adds the SA ladder    (J_max, J_sum)
   portfolio:   :class:`~repro.core.refine.PortfolioRefiner` — K      (J_max, J_sum)
                batched annealing starts, never worse than annealed:
   sharded:     :class:`~repro.core.refine.ShardedPortfolioRefiner`   (J_max, J_sum)
                — the portfolio partitioned into seed blocks run in
                parallel worker processes; bit-identical to
                ``portfolio[k=K]:`` for any shard count, plus optional
                adaptive restart/retune control (``restarts=auto``)
   device:      :class:`~repro.core.refine.DevicePortfolioRefiner`    (J_max, J_sum)
                — the portfolio's K ladders resident on the
                accelerator (vmapped Metropolis moves over stacked
                crossing-count state, one ``lax.scan`` per
                temperature); same boundary protocol, scales to
                K=1024; delegates to ``portfolio:`` without jax
   hier:        :class:`~repro.core.refine.HierRefiner` — recursive   (J_max, J_sum)
                multilevel mapping down a topology tree: group the
                nodes by per-level fan-outs
                (``hier[fanouts=16x16]:``), solve each level's much
                smaller restricted problem with any registered
                refiner (default ``annealed``; per level via
                ``hier[levels=rack:portfolio[k=8],pod:annealed]:``),
                recurse into each subtree
   ============ ===================================================== =========

Every spelling accepted here is accepted everywhere a mapper name appears:
``device_layout`` / ``mapped_device_array`` (:mod:`repro.core.remap`),
``make_mapped_mesh`` (:mod:`repro.launch.mesh`), the benchmark drivers,
and :func:`~repro.core.plan.cart_create`.  Prefixes chain —
``"portfolio[k=8]:refined:hyperplane"`` applies swap refinement, then the
portfolio, inner-first — because ``<base>`` is itself resolved by this
grammar.

The grammar's one implementation is :func:`~repro.core.plan.parse_plan`,
which turns a spelling into a typed, composable
:class:`~repro.core.plan.MappingPlan` (stage chain); :func:`get_mapper` is
its Mapper-shaped front-end, re-packaging the parsed stages as nested
:class:`~repro.core.refine.RefinedMapper` wrappers.  Programs wanting
stage chains, per-stage budgets, or cached solves should use ``parse_plan``
/ :class:`~repro.core.plan.PlanCache` directly.  :func:`split_mapper_name`
exposes the raw parse (prefix, options, base) for callers that need to
inspect a spelling without instantiating it.

Usage::

    get_mapper("hyperplane")                       # paper §V.B
    get_mapper("refined:kdtree", policy="steepest")
    get_mapper("annealed:nodecart", seed=7).assignment(grid, stencil, sizes)
    get_mapper("portfolio[k=4,kill_factor=1.25]:hyperplane")
    get_mapper("annealed[tol=1e-9,seed=-3]:kdtree")  # scientific/negative ok
    get_mapper("sharded[shards=4,k=64,restarts=auto]:hyperplane")
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple, Type

from .base import Mapper, MapperInapplicable, aggregate_node_size, check_bijection
from .blocked import BlockedMapper
from .graphgreedy import GraphGreedyMapper
from .hyperplane import HyperplaneMapper
from .kdtree import KDTreeMapper
from .nodecart import NodecartMapper
from .random_map import RandomMapper
from .stencil_strips import StencilStripsMapper

MAPPERS: Dict[str, Type[Mapper]] = {
    "blocked": BlockedMapper,
    "random": RandomMapper,
    "nodecart": NodecartMapper,
    "hyperplane": HyperplaneMapper,
    "kdtree": KDTreeMapper,
    "stencil_strips": StencilStripsMapper,
    "graphgreedy": GraphGreedyMapper,
}

#: Prefix turning any registered mapper into its local-search variant.
REFINED_PREFIX = "refined:"
#: Prefix for the alternating j_sum/j_max scheduled refiner.
SCHEDULED_PREFIX = "refined2:"
#: Prefix for the scheduled refiner with the simulated-annealing ladder.
ANNEALED_PREFIX = "annealed:"
#: Prefix for the K-start batched annealing portfolio.
PORTFOLIO_PREFIX = "portfolio:"
#: Prefix for the process-sharded adaptive portfolio engine.
SHARDED_PREFIX = "sharded:"
#: Prefix for the device-resident (jax) annealing portfolio engine.
DEVICE_PREFIX = "device:"
#: Prefix for the recursive multilevel (topology-tree) mapping stage.
HIER_PREFIX = "hier:"

#: All refinement prefixes, in registry-listing order.
REFINE_PREFIXES = (REFINED_PREFIX, SCHEDULED_PREFIX, ANNEALED_PREFIX,
                   PORTFOLIO_PREFIX, SHARDED_PREFIX, DEVICE_PREFIX,
                   HIER_PREFIX)

#: the leading ``<prefix>`` of an option-bearing prefixed spelling; the
#: bracket body is scanned with balanced-depth counting (not a regex) so
#: option values may themselves carry brackets
#: (``hier[levels=rack:portfolio[k=8],pod:annealed]:<base>``).
_PREFIX_HEAD_RE = re.compile(r"^(?P<prefix>[a-z][a-z0-9_]*)")

#: a plain option key (what may appear left of ``=``); anything else left
#: of the first ``=`` marks a continuation of the previous option's value.
_OPTION_KEY_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def _coerce_option(value: str):
    """Bracket-option values: int, then float, then bool / None, else
    string.  Everything Python's numeric constructors accept works —
    negative numbers, scientific notation (``t0=1e-2`` / ``seed=-3``,
    pinned by tests), ``inf``, underscore groupings."""
    for cast in (int, float):
        try:
            return cast(value)
        except ValueError:
            pass
    if value in ("true", "True"):
        return True
    if value in ("false", "False"):
        return False
    if value in ("none", "None"):
        return None
    return value


def _spelling(name: Optional[str]) -> str:
    """Error-message suffix naming the full spelling being parsed."""
    return f" in mapper name {name!r}" if name else ""


def _split_depth0(body: str) -> list:
    """Split on commas at bracket depth 0 only, so option values may carry
    bracketed sub-spellings (``levels=rack:portfolio[k=8,seed=3]``)."""
    parts, cur, depth = [], [], 0
    for ch in body:
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
            continue
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth = max(0, depth - 1)
        cur.append(ch)
    parts.append("".join(cur))
    return parts


def parse_mapper_options(opts: str,
                         name: Optional[str] = None) -> Dict[str, object]:
    """Parse a bracket-option body (``"k=8,seed=-3,tol=1e-9"``) into kwargs.

    Splitting happens on depth-0 commas only, and an item that is *not* a
    plain ``key=value`` (its text left of the first ``=`` is no identifier,
    or it has no ``=`` but contains a ``:`` sub-spelling) **continues the
    previous option's value** — that is how
    ``hier[levels=rack:portfolio[k=8],pod:annealed]`` keeps
    ``pod:annealed`` inside ``levels`` while a bare ``annealed[k]`` still
    raises.  ``name`` (the full spelling the body came from) is quoted in
    every error message so a failure deep in a chained prefix stays
    attributable."""
    out: Dict[str, object] = {}
    last_key: Optional[str] = None
    for item in _split_depth0(opts):
        item = item.strip()
        if not item:
            continue
        key, sep, value = item.partition("=")
        key = key.strip()
        plain_key = bool(sep) and bool(_OPTION_KEY_RE.match(key))
        if not plain_key:
            # continuation of the previous value (a comma inside a nested
            # spelling, e.g. per-level solver lists) — only recognizable
            # as such when it carries a `:`-sub-spelling or an `=` deeper
            # inside; a bare word stays the pinned key=value error.
            if last_key is not None and (":" in item or sep):
                prev = out[last_key]
                out[last_key] = (prev if isinstance(prev, str)
                                 else str(prev)) + "," + item
                continue
            raise ValueError(
                f"bad mapper option {item!r}{_spelling(name)}: "
                f"expected key=value")
        if key in out:
            raise ValueError(
                f"duplicate mapper option {key!r}{_spelling(name)}")
        out[key] = _coerce_option(value.strip())
        last_key = key
    return out


def split_mapper_list(spec: str) -> list:
    """Split a comma-separated list of mapper spellings on commas *outside*
    bracket options: ``"blocked,portfolio[k=8,seed=3]:kdtree"`` -> two
    entries (depth-counted, so nested brackets nest).  The one splitter
    the CLI drivers share."""
    parts, cur, depth = [], [], 0
    for ch in spec:
        if ch == "," and depth == 0:
            if cur:
                parts.append("".join(cur))
            cur = []
            continue
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth = max(0, depth - 1)
        cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def split_mapper_name(name: str, full_name: Optional[str] = None) \
        -> Optional[Tuple[str, Dict[str, object], str]]:
    """Split a refinement-prefixed spelling into ``(prefix, options,
    base_name)``; None if ``name`` is not a refinement spelling.  The
    prefix is returned without the colon (``"portfolio"``), options as a
    kwargs dict (empty when no bracket is present).  The bracket body is
    scanned with balanced-depth counting, so values may nest brackets
    (``hier[levels=rack:portfolio[k=8]]:<base>``).  ``full_name`` names
    the enclosing spelling in option-parse errors (chained prefixes hand
    the original spelling down)."""
    m = _PREFIX_HEAD_RE.match(name)
    if m is None or m.group("prefix") + ":" not in REFINE_PREFIXES:
        return None
    prefix = m.group("prefix")
    i = m.end()
    opts = ""
    if i < len(name) and name[i] == "[":
        depth = 0
        j = i
        for j in range(i, len(name)):
            if name[j] == "[":
                depth += 1
            elif name[j] == "]":
                depth -= 1
                if depth == 0:
                    break
        if depth != 0:                    # unbalanced bracket: not ours
            return None
        opts = name[i + 1:j]
        i = j + 1
    if i >= len(name) or name[i] != ":" or i + 1 >= len(name):
        return None
    return (prefix,
            parse_mapper_options(opts, name=full_name or name),
            name[i + 1:])


def _make_refiner(prefix: str, kwargs: Dict[str, object]):
    from ..refine import (DevicePortfolioRefiner, HierRefiner,
                          PortfolioRefiner, ScheduledRefiner,
                          ShardedPortfolioRefiner)
    if prefix == "refined":
        return None                       # RefinedMapper's default SwapRefiner
    if prefix == "refined2":
        return ScheduledRefiner(**kwargs)
    if prefix == "annealed":
        return ScheduledRefiner(anneal=True, **kwargs)
    if prefix == "portfolio":
        return PortfolioRefiner(**kwargs)
    if prefix == "sharded":
        return ShardedPortfolioRefiner(**kwargs)
    if prefix == "device":
        return DevicePortfolioRefiner(**kwargs)
    if prefix == "hier":
        return HierRefiner(**kwargs)
    raise KeyError(prefix)  # pragma: no cover - guarded by split_mapper_name


def get_mapper(name: str, **kwargs) -> Mapper:
    """Instantiate a mapper by name (see the module docstring for the full
    resolution contract; :func:`~repro.core.plan.parse_plan` is the
    grammar's implementation — this is its Mapper-shaped front-end).

    ``"refined:<base>"`` wraps ``<base>`` with swap-refinement local search,
    ``"refined2:<base>"`` with the alternating j_sum/j_max schedule,
    ``"annealed:<base>"`` adds the simulated-annealing ladder, and
    ``"portfolio:<base>"`` runs K batched annealing starts; prefixes chain
    (``"portfolio:refined:<base>"``).  ``kwargs`` and bracket options
    (``"portfolio[k=8]:<base>"``; bracket wins on conflict) configure the
    outermost refiner, not the base algorithm; every prefix composes with
    every key in :data:`MAPPERS`.  The returned mapper carries the
    canonical ``plan_key`` spelling, so :class:`~repro.core.plan.PlanCache`
    can key solved assignments off it.
    """
    from ..plan import parse_plan
    return parse_plan(name, **kwargs).to_mapper()


def available_mappers(include_refined: bool = True) -> list:
    """All resolvable mapper names (base + their refined variants)."""
    names = sorted(MAPPERS)
    if include_refined:
        for prefix in REFINE_PREFIXES:
            names += [prefix + n for n in sorted(MAPPERS)]
    return names


__all__ = [
    "Mapper", "MapperInapplicable", "aggregate_node_size", "check_bijection",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "MAPPERS", "REFINED_PREFIX", "SCHEDULED_PREFIX", "ANNEALED_PREFIX",
    "PORTFOLIO_PREFIX", "SHARDED_PREFIX", "DEVICE_PREFIX", "HIER_PREFIX",
    "REFINE_PREFIXES", "get_mapper",
    "available_mappers", "split_mapper_name", "split_mapper_list",
    "parse_mapper_options",
]
