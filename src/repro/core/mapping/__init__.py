"""Process-to-node mapping algorithms (paper §V + baselines §III)."""
from __future__ import annotations

from typing import Dict, Type

from .base import Mapper, MapperInapplicable, aggregate_node_size, check_bijection
from .blocked import BlockedMapper
from .graphgreedy import GraphGreedyMapper
from .hyperplane import HyperplaneMapper
from .kdtree import KDTreeMapper
from .nodecart import NodecartMapper
from .random_map import RandomMapper
from .stencil_strips import StencilStripsMapper

MAPPERS: Dict[str, Type[Mapper]] = {
    "blocked": BlockedMapper,
    "random": RandomMapper,
    "nodecart": NodecartMapper,
    "hyperplane": HyperplaneMapper,
    "kdtree": KDTreeMapper,
    "stencil_strips": StencilStripsMapper,
    "graphgreedy": GraphGreedyMapper,
}

#: Prefix turning any registered mapper into its local-search variant.
REFINED_PREFIX = "refined:"


def get_mapper(name: str, **kwargs) -> Mapper:
    """Instantiate a mapper by name.

    ``"refined:<base>"`` wraps ``<base>`` with swap-refinement local search
    (``kwargs`` then configure the refiner, not the base algorithm); the
    prefix composes with every key in :data:`MAPPERS`.
    """
    if name.startswith(REFINED_PREFIX):
        from ..refine import RefinedMapper
        base = get_mapper(name[len(REFINED_PREFIX):])
        return RefinedMapper(base, **kwargs)
    try:
        cls = MAPPERS[name]
    except KeyError:
        raise KeyError(
            f"unknown mapper {name!r}; choose from {sorted(MAPPERS)} "
            f"or '{REFINED_PREFIX}<base>'")
    return cls(**kwargs)


def available_mappers(include_refined: bool = True) -> list:
    """All resolvable mapper names (base + their refined variants)."""
    names = sorted(MAPPERS)
    if include_refined:
        names += [REFINED_PREFIX + n for n in sorted(MAPPERS)]
    return names


__all__ = [
    "Mapper", "MapperInapplicable", "aggregate_node_size", "check_bijection",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "MAPPERS", "REFINED_PREFIX", "get_mapper", "available_mappers",
]
