"""Process-to-node mapping algorithms (paper §V + baselines §III).

Mapper-name resolution contract
-------------------------------

:func:`get_mapper` turns a string into a ready :class:`Mapper` instance.
Names resolve in two layers:

1. **Base algorithms** — exact keys of :data:`MAPPERS` (``"blocked"``,
   ``"random"``, ``"nodecart"``, ``"hyperplane"``, ``"kdtree"``,
   ``"stencil_strips"``, ``"graphgreedy"``).  ``kwargs`` go to the
   algorithm's constructor.
2. **Refinement prefixes** — ``"<prefix>:<base>"`` recursively resolves
   ``<base>`` (so a base's own name rules apply unchanged) and wraps it in
   a :class:`~repro.core.refine.RefinedMapper`.  ``kwargs`` then configure
   the *refiner*, not the base algorithm:

   ========== ===================================================== =========
   prefix     refiner                                               objective
   ========== ===================================================== =========
   refined:   :class:`~repro.core.refine.SwapRefiner`               J_sum
   refined2:  :class:`~repro.core.refine.ScheduledRefiner`          (J_max, J_sum)
   annealed:  ScheduledRefiner(anneal=True) — adds the SA ladder    (J_max, J_sum)
   ========== ===================================================== =========

   Prefixes do not stack (``"refined:refined:blocked"`` is rejected by the
   recursive base lookup, since prefixed names are never registry keys).

Every spelling accepted here is accepted everywhere a mapper name appears:
``device_layout`` / ``mapped_device_array`` (:mod:`repro.core.remap`),
``make_mapped_mesh`` (:mod:`repro.launch.mesh`), and the benchmark drivers.

Usage::

    get_mapper("hyperplane")                       # paper §V.B
    get_mapper("refined:kdtree", policy="steepest")
    get_mapper("annealed:nodecart", seed=7).assignment(grid, stencil, sizes)
"""
from __future__ import annotations

from typing import Dict, Type

from .base import Mapper, MapperInapplicable, aggregate_node_size, check_bijection
from .blocked import BlockedMapper
from .graphgreedy import GraphGreedyMapper
from .hyperplane import HyperplaneMapper
from .kdtree import KDTreeMapper
from .nodecart import NodecartMapper
from .random_map import RandomMapper
from .stencil_strips import StencilStripsMapper

MAPPERS: Dict[str, Type[Mapper]] = {
    "blocked": BlockedMapper,
    "random": RandomMapper,
    "nodecart": NodecartMapper,
    "hyperplane": HyperplaneMapper,
    "kdtree": KDTreeMapper,
    "stencil_strips": StencilStripsMapper,
    "graphgreedy": GraphGreedyMapper,
}

#: Prefix turning any registered mapper into its local-search variant.
REFINED_PREFIX = "refined:"
#: Prefix for the alternating j_sum/j_max scheduled refiner.
SCHEDULED_PREFIX = "refined2:"
#: Prefix for the scheduled refiner with the simulated-annealing ladder.
ANNEALED_PREFIX = "annealed:"

#: All refinement prefixes, in registry-listing order.
REFINE_PREFIXES = (REFINED_PREFIX, SCHEDULED_PREFIX, ANNEALED_PREFIX)


def get_mapper(name: str, **kwargs) -> Mapper:
    """Instantiate a mapper by name (see the module docstring for the full
    resolution contract).

    ``"refined:<base>"`` wraps ``<base>`` with swap-refinement local search,
    ``"refined2:<base>"`` with the alternating j_sum/j_max schedule, and
    ``"annealed:<base>"`` adds the simulated-annealing ladder (``kwargs``
    then configure the refiner, not the base algorithm); every prefix
    composes with every key in :data:`MAPPERS`.
    """
    if name.startswith(REFINED_PREFIX):
        from ..refine import RefinedMapper
        base = get_mapper(name[len(REFINED_PREFIX):])
        return RefinedMapper(base, **kwargs)
    if name.startswith(SCHEDULED_PREFIX):
        from ..refine import RefinedMapper, ScheduledRefiner
        base = get_mapper(name[len(SCHEDULED_PREFIX):])
        return RefinedMapper(base, refiner=ScheduledRefiner(**kwargs),
                             prefix="refined2")
    if name.startswith(ANNEALED_PREFIX):
        from ..refine import RefinedMapper, ScheduledRefiner
        base = get_mapper(name[len(ANNEALED_PREFIX):])
        return RefinedMapper(base,
                             refiner=ScheduledRefiner(anneal=True, **kwargs),
                             prefix="annealed")
    try:
        cls = MAPPERS[name]
    except KeyError:
        raise KeyError(
            f"unknown mapper {name!r}; choose from {sorted(MAPPERS)} "
            f"or one of {[p + '<base>' for p in REFINE_PREFIXES]}")
    return cls(**kwargs)


def available_mappers(include_refined: bool = True) -> list:
    """All resolvable mapper names (base + their refined variants)."""
    names = sorted(MAPPERS)
    if include_refined:
        for prefix in REFINE_PREFIXES:
            names += [prefix + n for n in sorted(MAPPERS)]
    return names


__all__ = [
    "Mapper", "MapperInapplicable", "aggregate_node_size", "check_bijection",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "MAPPERS", "REFINED_PREFIX", "SCHEDULED_PREFIX", "ANNEALED_PREFIX",
    "REFINE_PREFIXES", "get_mapper", "available_mappers",
]
