"""Process-to-node mapping algorithms (paper §V + baselines §III)."""
from __future__ import annotations

from typing import Dict, Type

from .base import Mapper, MapperInapplicable, aggregate_node_size, check_bijection
from .blocked import BlockedMapper
from .graphgreedy import GraphGreedyMapper
from .hyperplane import HyperplaneMapper
from .kdtree import KDTreeMapper
from .nodecart import NodecartMapper
from .random_map import RandomMapper
from .stencil_strips import StencilStripsMapper

MAPPERS: Dict[str, Type[Mapper]] = {
    "blocked": BlockedMapper,
    "random": RandomMapper,
    "nodecart": NodecartMapper,
    "hyperplane": HyperplaneMapper,
    "kdtree": KDTreeMapper,
    "stencil_strips": StencilStripsMapper,
    "graphgreedy": GraphGreedyMapper,
}


def get_mapper(name: str, **kwargs) -> Mapper:
    try:
        cls = MAPPERS[name]
    except KeyError:
        raise KeyError(f"unknown mapper {name!r}; choose from {sorted(MAPPERS)}")
    return cls(**kwargs)


__all__ = [
    "Mapper", "MapperInapplicable", "aggregate_node_size", "check_bijection",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "MAPPERS", "get_mapper",
]
