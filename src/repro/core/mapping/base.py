"""Mapper interface (paper §V preamble).

A mapper computes, for every rank ``r`` (process/device), its *new coordinate*
in the Cartesian grid.  The scheduler's allocation is blocked — ranks
``0..n_0-1`` live on node 0, the next ``n_1`` on node 1, ... — and must be
respected, so the induced node-of-grid-position assignment is
``node_of_pos[coord(r)] = blocked_node(r)``.

The paper's algorithms are *fully distributed*: each rank can compute
``coord_of_rank(dims, stencil, n, r)`` from the inputs alone.  We expose that
per-rank form where the algorithm admits it, plus a batch ``coords()`` used
for evaluation and mesh construction.
"""
from __future__ import annotations

import abc
import math
from typing import Optional, Sequence

import numpy as np

from ..cost import MappingCost, evaluate, node_of_rank_blocked
from ..grid import CartGrid
from ..stencil import Stencil

__all__ = ["Mapper", "MapperInapplicable", "aggregate_node_size", "check_bijection"]


class MapperInapplicable(ValueError):
    """Raised when an algorithm's preconditions don't hold (e.g. Nodecart
    with heterogeneous node sizes or a non-factorizable layout)."""


def aggregate_node_size(node_sizes: Sequence[int], mode: str = "mean") -> int:
    """Heterogeneous-node handling (paper §V.A): collapse n_i to a single n."""
    sizes = np.asarray(node_sizes, dtype=np.int64)
    if mode == "mean":
        return max(1, int(round(float(sizes.mean()))))
    if mode == "min":
        return int(sizes.min())
    if mode == "max":
        return int(sizes.max())
    raise ValueError(f"unknown aggregate mode {mode!r}")


def check_bijection(coords: np.ndarray, dims: Sequence[int]) -> None:
    """Assert the rank->coordinate map is a bijection onto the grid."""
    p = int(math.prod(dims))
    if coords.shape != (p, len(dims)):
        raise AssertionError(f"coords shape {coords.shape} != ({p}, {len(dims)})")
    flat = np.ravel_multi_index(tuple(coords.T), tuple(dims))
    if len(np.unique(flat)) != p:
        raise AssertionError("rank->coordinate map is not a bijection")


class Mapper(abc.ABC):
    """Base class for process-to-node mapping algorithms."""

    name: str = "base"
    #: True if the algorithm needs a single homogeneous node size.
    requires_homogeneous: bool = False
    #: Canonical plan spelling (set when built via ``parse_plan`` /
    #: ``get_mapper``) — the stable :class:`~repro.core.plan.PlanCache`
    #: identity; None means "no stable key, don't cache".  The key is a
    #: construction-time snapshot: if you mutate a mapper's configuration
    #: afterwards (e.g. ``m.refiner.seed = 5``), set ``m.plan_key = None``
    #: or the cache will serve results solved under the old configuration.
    plan_key: Optional[str] = None

    @abc.abstractmethod
    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        """(p, d) new coordinate for every rank."""

    # -- derived ------------------------------------------------------------
    def assignment(self, grid: CartGrid, stencil: Stencil,
                   node_sizes: Sequence[int]) -> np.ndarray:
        """(p,) node id owning each grid *position* (row-major raveled)."""
        sizes = np.asarray(node_sizes, dtype=np.int64)
        if int(sizes.sum()) != grid.size:
            raise ValueError(
                f"sum(node_sizes)={int(sizes.sum())} != grid size {grid.size}")
        coords = self.coords(grid, stencil, node_sizes)
        check_bijection(coords, grid.dims)
        owner_of_rank = node_of_rank_blocked(node_sizes)
        node_of_pos = np.empty(grid.size, dtype=np.int64)
        flat = np.ravel_multi_index(tuple(coords.T), grid.dims)
        node_of_pos[flat] = owner_of_rank
        return node_of_pos

    def cost(self, grid: CartGrid, stencil: Stencil, node_sizes: Sequence[int],
             weighted: bool = False) -> MappingCost:
        return evaluate(grid, stencil, self.assignment(grid, stencil, node_sizes),
                        num_nodes=len(node_sizes), weighted=weighted)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Mapper {self.name}>"
