"""Random mapping baseline (paper §VI, "Random" column of Tables II-VII)."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from .base import Mapper

__all__ = ["RandomMapper"]


class RandomMapper(Mapper):
    name = "random"

    def __init__(self, seed: int = 0):
        self.seed = int(seed)

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        perm = rng.permutation(grid.size)
        return np.stack(np.unravel_index(perm, grid.dims), axis=1)
