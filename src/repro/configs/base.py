"""Architecture + run configuration.

One :class:`ArchConfig` per assigned architecture (``src/repro/configs/<id>.py``)
with the exact published dimensions, plus ``reduced()`` variants of the same
family for CPU smoke tests.  Analytic parameter/FLOP counts live here so the
roofline's MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) is config-derived,
not hand-entered.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# the assigned input-shape set (applies to every LM-family arch)
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # SWA width (mixtral/zamba2 long ctx)
    attention_free: bool = False
    # MoE
    n_experts: int = 0
    n_dense_layers: int = 0         # leading dense layers (DeepSeek-V3: 3)
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MTP (deepseek)
    mtp_depth: int = 0
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every k SSM layers
    attn_every: int = 0
    # enc-dec (seamless)
    n_enc_layers: int = 0
    src_len: int = 0                # encoder source length for enc-dec shapes
    # vlm / audio stub frontends
    num_patches: int = 0            # prepended visual/audio embeddings
    # numerics / runtime knobs (hillclimb surface)
    swa_ring_cache: bool = False    # ring KV cache of window size for SWA
                                    # decode (beyond-paper, §Perf)
    use_pallas_attention: bool = False  # route full-sequence attention
                                        # through kernels/attention (TPU;
                                        # interpret-mode on CPU)
    param_dtype: str = "bf16"
    compute_dtype: str = "bf16"
    remat: str = "full"             # full | dots | none
    scan_layers: bool = True
    microbatches: int = 1
    use_mtp_loss: bool = False
    quantized_opt_state: bool = False
    tie_embeddings: bool = False
    source: str = ""                # provenance note

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables padded to a multiple of 256 so the vocab dim
        always divides the 16/32-way mesh axes (GPT-NeoX-style padding; the
        published vocab is kept for data/loss semantics).  Without this,
        e.g. mamba2's 50280 falls back to full logits replication."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_headdim

    # -- analytic parameter counts -------------------------------------
    def attn_params(self) -> int:
        d = self.d_model
        if self.attention_free:
            return 0
        if self.use_mla:
            q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim)
            kv += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            return q + kv + o + self.q_lora_rank + self.kv_lora_rank  # + norms
        hd = self.hd
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def mlp_params_dense(self, d_ff: Optional[int] = None) -> int:
        f = d_ff if d_ff is not None else self.d_ff
        return 3 * self.d_model * f  # SwiGLU: gate, up, down

    def ssm_params(self) -> int:
        di, n, hd = self.d_inner_ssm, self.ssm_state, self.ssm_headdim
        heads = self.n_ssm_heads
        in_proj = self.d_model * (2 * di + 2 * n + heads)  # z, x, B, C, dt
        conv = (di + 2 * n) * self.ssm_conv
        out = di * self.d_model
        extra = heads * 2 + di  # A, dt_bias, D skip
        return in_proj + conv + out + extra

    def layer_params(self, layer_idx: int = 0) -> int:
        d = self.d_model
        norms = 2 * d
        if self.family == "ssm":
            return self.ssm_params() + d
        if self.family == "hybrid":
            return self.ssm_params() + d  # shared attn counted once globally
        p = self.attn_params() + norms
        if (self.n_experts > 0) and layer_idx >= self.n_dense_layers:
            fe = self.d_ff_expert or self.d_ff
            p += self.n_experts * 3 * d * fe
            p += self.n_shared_experts * 3 * d * fe
            p += d * self.n_experts  # router
        else:
            p += self.mlp_params_dense()
        return p

    def active_layer_params(self, layer_idx: int = 10**9) -> int:
        d = self.d_model
        if self.family in ("ssm",):
            return self.ssm_params() + d
        if self.family == "hybrid":
            return self.ssm_params() + d
        p = self.attn_params() + 2 * d
        if self.n_experts > 0 and layer_idx >= self.n_dense_layers:
            fe = self.d_ff_expert or self.d_ff
            p += (self.top_k + self.n_shared_experts) * 3 * d * fe
            p += d * self.n_experts
        else:
            p += self.mlp_params_dense()
        return p

    def param_count(self) -> int:
        nd = self.n_dense_layers
        total = (self.n_layers - nd) * self.layer_params(nd) \
            + nd * self.layer_params(0)
        if self.family == "hybrid" and self.attn_every:
            total += self.attn_params() + self.mlp_params_dense() + 2 * self.d_model
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.n_enc_layers * (self.attn_params() + self.mlp_params_dense()
                                       + 2 * self.d_model)
            dec_cross = self.n_layers * self.attn_params()
            total += enc + dec_cross
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        total += emb + head + self.d_model
        if self.mtp_depth:
            total += self.mtp_depth * self.layer_params(self.n_layers)
        return total

    def active_param_count(self) -> int:
        nd = self.n_dense_layers
        total = (self.n_layers - nd) * self.active_layer_params() \
            + nd * self.active_layer_params(0)
        if self.family == "hybrid" and self.attn_every:
            total += self.attn_params() + self.mlp_params_dense() + 2 * self.d_model
        if self.family == "encdec":
            enc = self.n_enc_layers * (self.attn_params() + self.mlp_params_dense()
                                       + 2 * self.d_model)
            total += enc + self.n_layers * self.attn_params()
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        return total + emb + head + self.d_model

    # -- analytic FLOPs --------------------------------------------------
    def model_flops(self, shape: ShapeSpec) -> float:
        """The assignment's MODEL_FLOPS: 6·N_active·D (train) / 2·N_active·D
        (inference), D = tokens processed in the step."""
        if shape.kind == "train":
            tokens = shape.seq_len * shape.global_batch
            return 6.0 * self.active_param_count() * tokens
        if shape.kind == "prefill":
            tokens = shape.seq_len * shape.global_batch
            return 2.0 * self.active_param_count() * tokens
        # decode: one token per sequence
        return 2.0 * self.active_param_count() * shape.global_batch

    def attn_flops(self, shape: ShapeSpec) -> float:
        """Analytic attention/SSM mixing FLOPs 6·N·D misses — dominates long
        contexts (e.g. MLA latent scores against a 32k cache).  Added to
        MODEL_FLOPS for the useful-ratio so genuinely useful attention work
        is not booked as waste."""
        B, S = shape.global_batch, shape.seq_len
        fwd_mult = 3.0 if shape.kind == "train" else 1.0
        if self.family in ("ssm",) or self.attn_every:
            # SSD: intra-chunk dual form + state in/out per token
            tokens = B * (S if shape.kind != "decode" else 1)
            di, N, Q = self.d_inner_ssm, self.ssm_state, self.ssm_chunk
            per_tok = 2.0 * (Q if shape.kind != "decode" else 1) * (N + di) \
                + 4.0 * di * N
            n_ssm = self.n_layers
            f = fwd_mult * tokens * per_tok * n_ssm
            if not self.attn_every:
                return f
            # hybrid: shared attention applied every attn_every layers
            n_attn = self.n_layers // self.attn_every
        else:
            n_attn = self.n_layers
            f = 0.0
        if self.attention_free:
            return f
        if self.use_mla:
            qk = self.kv_lora_rank + self.qk_rope_dim
            hv = self.kv_lora_rank
        else:
            qk = hv = self.hd
        per_pair = 2.0 * self.n_heads * (qk + hv)
        if shape.kind == "decode":
            ctx = min(S, self.sliding_window or S)
            f += B * ctx * per_pair * n_attn
            if self.family == "encdec":       # cross-attention over memory
                f += B * self.src_len * per_pair * n_attn
        else:
            ctx = min(S, self.sliding_window or S)
            pairs = B * S * ctx * (0.5 if ctx == S else 1.0)
            f += fwd_mult * pairs * per_pair * n_attn
            if self.family == "encdec":
                f += fwd_mult * B * self.src_len ** 2 * per_pair \
                    * self.n_enc_layers            # bidirectional encoder
                f += fwd_mult * B * S * self.src_len * per_pair * n_attn
        return f

    # -- reductions for smoke tests --------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family, tiny dimensions — runs a CPU forward/train step."""
        hd = 16
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        changes = dict(
            name=self.name + "-reduced",
            n_layers=min(2 if not self.attn_every else max(2, self.attn_every),
                         self.n_layers),
            d_model=64, n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd,
            d_ff=128, vocab=256, param_dtype="f32", compute_dtype="f32",
            remat="none", microbatches=1,
        )
        if self.n_experts:
            changes.update(n_experts=4, top_k=min(2, self.top_k or 2),
                           d_ff_expert=64,
                           n_shared_experts=min(1, self.n_shared_experts),
                           n_dense_layers=min(1, self.n_dense_layers))
        if self.use_mla:
            changes.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                           qk_rope_dim=8, v_head_dim=16)
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.attn_every:
            changes.update(attn_every=2, n_layers=4)
        if self.n_enc_layers:
            changes.update(n_enc_layers=2, src_len=32)
        if self.num_patches:
            changes.update(num_patches=8)
        if self.mtp_depth:
            changes.update(mtp_depth=1)
        if self.sliding_window:
            changes.update(sliding_window=32)
        return replace(self, **changes)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs (DESIGN.md §5)."""
    if shape.name == "long_500k":
        subquad = (cfg.attention_free or cfg.attn_every > 0
                   or cfg.sliding_window is not None)
        if not subquad:
            return False, ("full-attention arch: 500k decode needs "
                           "sub-quadratic attention (skip per assignment)")
    return True, ""
