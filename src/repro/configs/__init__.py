from .base import SHAPES, ArchConfig, ShapeSpec, shape_applicable
from .registry import ARCHS, all_cells, get_arch

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable",
           "ARCHS", "get_arch", "all_cells"]
