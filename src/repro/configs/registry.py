"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

from typing import Dict

from .base import SHAPES, ArchConfig, ShapeSpec, shape_applicable
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .granite_20b import CONFIG as granite_20b
from .granite_3_8b import CONFIG as granite_3_8b
from .internvl2_76b import CONFIG as internvl2_76b
from .mamba2_130m import CONFIG as mamba2_130m
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .qwen3_8b import CONFIG as qwen3_8b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .yi_34b import CONFIG as yi_34b
from .zamba2_2_7b import CONFIG as zamba2_2_7b

ARCHS: Dict[str, ArchConfig] = {c.name: c for c in [
    mixtral_8x7b, deepseek_v3_671b, mamba2_130m, yi_34b, granite_3_8b,
    granite_20b, qwen3_8b, zamba2_2_7b, seamless_m4t_medium, internvl2_76b,
]}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_arch(name[:-len("-reduced")]).reduced()
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")


def all_cells():
    """All 40 (arch, shape) cells with applicability verdicts."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            out.append((arch, shape, ok, why))
    return out


__all__ = ["ARCHS", "SHAPES", "get_arch", "all_cells", "ArchConfig",
           "ShapeSpec", "shape_applicable"]
