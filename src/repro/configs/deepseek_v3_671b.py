"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

Adaptations recorded in DESIGN.md: quantized optimizer state on (int8
moments) so the 671B state fits v5e pods; first 3 layers dense (d_ff 18432)
per the published architecture, remaining 58 MoE.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,           # dense-layer FFN width
    d_ff_expert=2048,
    vocab=129280,
    n_experts=256,
    n_dense_layers=3,
    top_k=8,
    n_shared_experts=1,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    use_mtp_loss=True,
    quantized_opt_state=True,
    microbatches=8,
    source="arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3",
)
