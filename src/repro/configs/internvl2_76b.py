"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama3-70B-style) LLM backbone.
[arXiv:2404.16821; unverified]

Backbone only: the InternViT frontend is a stub — ``input_specs`` provides
precomputed patch embeddings (B, 256, d_model) prepended to the token
sequence per the assignment.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    num_patches=256,
    rope_theta=5e5,
    quantized_opt_state=True,
    microbatches=16,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-Llama3-76B",
)
