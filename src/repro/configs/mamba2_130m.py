"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) vocab=50280, ssm_state=128,
SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    attention_free=True,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    param_dtype="f32",   # 130M: small enough; matches reference training
    microbatches=2,
    source="arXiv:2405.21060",
)
