"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652; hf]

Note: 56 heads is not divisible by the 16-way model axis; the partitioner's
divisibility fallback replicates the head dim and shards the flattened
projection instead (DESIGN.md §4, sharding/partition.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    microbatches=8,
    source="arXiv:2403.04652; hf:01-ai/Yi-34B",
)
