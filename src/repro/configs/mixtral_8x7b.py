"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    d_ff_expert=14336,
    vocab=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    microbatches=4,
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1",
)
