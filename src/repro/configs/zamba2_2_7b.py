"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attention blocks.
[arXiv:2411.15242; hf]

Adaptation (DESIGN.md): one shared-weight attention+MLP block applied after
every 6 Mamba2 layers (9 groups); Zamba2's per-invocation LoRA deltas on the
shared block are omitted.  At long context the shared block uses SWA
(window 4096) — that is what makes the ``long_500k`` shape runnable.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=6,
    sliding_window=4096,
    microbatches=2,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)
