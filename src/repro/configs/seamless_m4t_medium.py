"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

Backbone only: the audio frontend is a stub — ``input_specs`` provides
precomputed frame embeddings (B, T_src, d_model) per the assignment.
12 encoder + 12 decoder layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    src_len=1024,        # encoder frame positions per sequence
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)
