"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155, GQA.  [hf:ibm-granite/granite-3.0-2b-base (family); hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    rope_theta=1e4,
    microbatches=8,
    source="hf:ibm-granite/granite-3.0-8b-base",
)
