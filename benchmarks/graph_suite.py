"""Graph-layer suite: the CommGraph generalization's pinned claims.

Two machine-checked claims back the arbitrary-sparse-graph PR
(``results/BENCH_10.json``):

(a) **spelling parity** — for *every* ``available_mappers()`` spelling,
    the ``graph:`` flavor of the plan (cost core driven by
    ``CommGraph.from_stencil`` slot decomposition) returns bit-identical
    labels and exactly equal J_max/J_sum to the native grid path on a
    4x4 nearest-neighbor instance, under a distinct plan key with
    independent cache entries (two cold misses, then two hits);
(b) **arch DCI** — on every architecture in the config registry, mapping
    the real communication graph (TP/DP rings + MoE all-to-all from
    :func:`~repro.core.graph.arch_comm_graph`) with the default graph
    plan lex-dominates the blocked identity layout, with a strict J_sum
    reduction on >= 3 archs, and the link-level replay
    (:func:`~repro.analysis.replay_graph`) agrees with the graph
    objective *exactly* (``dci_total == J_sum``,
    ``max_dci_pod == J_max``) on both layouts.

  PYTHONPATH=src python -m benchmarks.graph_suite
  PYTHONPATH=src python -m benchmarks.graph_suite --tiny
  PYTHONPATH=src python -m benchmarks.graph_suite --json results/BENCH_10.json
"""
import argparse
import json
import time

import numpy as np

from repro.analysis import replay_graph
from repro.configs import ARCHS
from repro.core import (MappingProblem, PlanCache, Stencil, arch_comm_graph,
                        graph_create, parse_plan)
from repro.core.mapping import available_mappers

#: claim (a) instance — small enough that all 56 spellings finish, rich
#: enough (two axes, four nodes) that broken slot wiring can't hide.
PARITY_DIMS = (4, 4)
PARITY_SIZES = (4, 4, 4, 4)

GRAPH_PLAN = "annealed:graphgreedy"   # claim (b) mapping plan
MIN_STRICT_WINS = 3                   # claim (b): strict J_sum win floor


def _parity_spellings(tiny: bool):
    names = available_mappers()
    if tiny:
        # device: compiles jax kernels, sharded: forks worker processes —
        # both covered by the full run; the smoke tier keeps the pure
        # in-process engines.
        names = [n for n in names
                 if not n.startswith(("device:", "sharded"))]
    return names


def run_parity(tiny: bool = False):
    """Claim (a): one row per spelling, grid path vs graph: path."""
    problem = MappingProblem(PARITY_DIMS,
                             Stencil.nearest_neighbor(len(PARITY_DIMS)),
                             PARITY_SIZES)
    rows = []
    for spelling in _parity_spellings(tiny):
        p_grid = parse_plan(spelling)
        p_graph = parse_plan("graph:" + spelling)
        t0 = time.perf_counter()
        s_grid = p_grid.solve(problem)
        t_grid = time.perf_counter() - t0
        t0 = time.perf_counter()
        s_graph = p_graph.solve(problem)
        t_graph = time.perf_counter() - t0
        cache = PlanCache(maxsize=64)
        cache.solve(problem, p_grid)
        cache.solve(problem, p_graph)
        cold = (cache.hits, cache.misses) == (0, 2)
        cache.solve(problem, p_grid)
        cache.solve(problem, p_graph)
        warm = (cache.hits, cache.misses) == (2, 2)
        rows.append({
            "spelling": spelling,
            "labels_equal": bool(np.array_equal(s_grid.assignment,
                                                s_graph.assignment)),
            "j_max_equal": s_grid.j_max == s_graph.j_max,
            "j_sum_equal": s_grid.j_sum == s_graph.j_sum,
            "keys_distinct": p_graph.key == "graph:" + p_grid.key,
            "cache_independent": cold and warm,
            "j_max": s_grid.j_max, "j_sum": s_grid.j_sum,
            "t_grid_s": t_grid, "t_graph_s": t_graph,
        })
    return rows


def run_arch_dci(tiny: bool = False):
    """Claim (b): one row per registry arch, mapped vs blocked DCI."""
    archs = list(ARCHS)
    num_devices, node_size, n_nodes = 64, 8, 8
    if tiny:
        archs, num_devices, node_size = archs[:3], 32, 4
    sizes = (node_size,) * n_nodes
    rows = []
    for name in archs:
        g = arch_comm_graph(name, num_devices)
        t0 = time.perf_counter()
        mapped = graph_create(g, node_sizes=sizes, plan=GRAPH_PLAN,
                              cache=False)
        t_map = time.perf_counter() - t0
        blocked = graph_create(g, node_sizes=sizes, reorder=False,
                               cache=False)
        rep_m = replay_graph(g, mapped.solution.assignment, sizes)
        rep_b = replay_graph(g, blocked.solution.assignment, sizes)
        rows.append({
            "arch": name, "num_devices": num_devices,
            "edges": int(len(g.indices)), "slots": len(g.slots()),
            "plan": mapped.plan_key,
            "j_sum_mapped": mapped.j_sum, "j_sum_blocked": blocked.j_sum,
            "j_max_mapped": mapped.j_max, "j_max_blocked": blocked.j_max,
            "j_sum_ratio": blocked.j_sum / max(1e-9, mapped.j_sum),
            "j_max_ratio": blocked.j_max / max(1e-9, mapped.j_max),
            "lex_no_worse": (mapped.j_max, mapped.j_sum)
                <= (blocked.j_max, blocked.j_sum),
            "strict_j_sum_win": mapped.j_sum < blocked.j_sum,
            "replay_exact": (rep_m.dci_total == mapped.j_sum
                             and rep_m.max_dci_pod() == mapped.j_max
                             and rep_b.dci_total == blocked.j_sum
                             and rep_b.max_dci_pod() == blocked.j_max),
            "t_map_s": t_map,
        })
    return rows


def validate_graph_claims(out):
    """The PR's acceptance bar, machine-checked (PASS/FAIL verdicts)."""
    claims = []
    par = out["parity"]
    bad = [r["spelling"] for r in par
           if not (r["labels_equal"] and r["j_max_equal"]
                   and r["j_sum_equal"] and r["keys_distinct"]
                   and r["cache_independent"])]
    claims.append(("PASS" if not bad else "FAIL")
                  + f": graph: flavor bit-identical to the grid path on "
                  f"all {len(par)} registered spellings, with distinct "
                  "plan keys and independent cache entries"
                  + (f" (violations: {bad})" if bad else ""))
    arch = out["arch_dci"]
    bad = [r["arch"] for r in arch if not r["replay_exact"]]
    claims.append(("PASS" if not bad else "FAIL")
                  + ": linksim replay agrees with the graph objective "
                  f"exactly on all {len(arch)} archs, both layouts "
                  "(dci_total == J_sum, max_dci_pod == J_max)"
                  + (f" (violations: {bad})" if bad else ""))
    bad = [r["arch"] for r in arch if not r["lex_no_worse"]]
    wins = sum(r["strict_j_sum_win"] for r in arch)
    ok = not bad and wins >= MIN_STRICT_WINS
    best = max(r["j_sum_ratio"] for r in arch)
    claims.append(("PASS" if ok else "FAIL")
                  + f": mapped comm graph lex-dominates blocked on all "
                  f"{len(arch)} archs with a strict J_sum win on "
                  f"{wins} >= {MIN_STRICT_WINS} (best {best:.2f}x)"
                  + (f" (lex violations: {bad})" if bad else ""))
    return claims


def print_graph_table(out):
    par = out["parity"]
    n_ok = sum(r["labels_equal"] and r["j_max_equal"] and r["j_sum_equal"]
               for r in par)
    print(f"parity: {n_ok}/{len(par)} spellings bit-identical "
          f"(grid {sum(r['t_grid_s'] for r in par):.1f}s, "
          f"graph {sum(r['t_graph_s'] for r in par):.1f}s)")
    for r in par:
        if not (r["labels_equal"] and r["cache_independent"]):
            print(f"  MISMATCH {r['spelling']}")
    print()
    print(f"{'arch':22s} {'edges':>6s} {'slots':>5s} {'Jsum_blk':>10s} "
          f"{'Jsum_map':>10s} {'redux':>7s} {'Jmax_rx':>7s} {'exact':>5s} "
          f"{'t_map':>7s}")
    for r in out["arch_dci"]:
        print(f"{r['arch']:22s} {r['edges']:6d} {r['slots']:5d} "
              f"{r['j_sum_blocked']:10.3g} {r['j_sum_mapped']:10.3g} "
              f"{r['j_sum_ratio']:6.2f}x {r['j_max_ratio']:6.2f}x "
              f"{'yes' if r['replay_exact'] else 'NO':>5s} "
              f"{r['t_map_s']:6.2f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="in-process spellings + 3 archs at 32 devices "
                         "(CI smoke)")
    ap.add_argument("--json", default=None, help="dump rows + claims")
    args = ap.parse_args()
    out = {"parity": run_parity(args.tiny),
           "arch_dci": run_arch_dci(args.tiny)}
    print_graph_table(out)
    print()
    claims = validate_graph_claims(out)
    for c in claims:
        print("# " + c)
    out["claims"] = claims
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=float)
    if any(c.startswith("FAIL") for c in claims):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
