"""Serving-layer suite: the resident plan server's pinned claims.

Four machine-checked claims back the mapping-as-a-service PR
(``results/BENCH_9.json``):

(a) **bit-identity** — a ``sharded[...]`` plan served through the
    :class:`~repro.serving.PlanServer`'s persistent-worker engine returns
    the exact layout, J_max, and J_sum of the stateless cold-process
    ``cart_create`` at equal config, on every instance;
(b) **IPC reduction** — per temperature boundary, the resident protocol
    (leader keys + kill/restart masks) moves >= 10x fewer bytes than the
    stateless ``_block_step``'s payload re-ship.  Both sides are
    *measured*: the stateless engine under
    :func:`~repro.core.refine.sharded.measure_ipc` (pickled payload +
    result sizes), the resident pool via its byte-exact framed-pickle
    counters;
(c) **warm-serve latency** — a warm ``cart_create`` through the server
    (cache hit) lands at p50 <= 0.1x the cold-process solve wall-time;
(d) **anytime** — a deadlined request always returns a *valid* plan
    (scheduler cardinalities realized) within its deadline, with
    J_max <= 1.2x the undeadlined solve's.

  PYTHONPATH=src python -m benchmarks.serve_suite
  PYTHONPATH=src python -m benchmarks.serve_suite --quick
  PYTHONPATH=src python -m benchmarks.serve_suite --json results/BENCH_9.json
"""
import argparse
import json
import time

import numpy as np

from repro.core import CartGrid, Stencil, evaluate, get_mapper
from repro.core.plan import (MappingProblem, PlanCache, cart_create,
                             parse_plan)
from repro.core.refine.sharded import measure_ipc
from repro.serving import PlanClient, PlanServer, ResidentShardedRefiner

#: (label, dims, node_sizes, plan) — ragged instances (the regime the
#: refiners exist for), sized so boundary wall-times dominate overheads.
INSTANCES = [
    ("2d-6x8-ragged", (6, 8), [16, 16, 10, 6],
     "sharded[shards=2,k=8,restarts=auto]:hyperplane"),
    ("2d-16x28-ragged", (16, 28), [32] * 10 + [16] * 4 + [32] * 2,
     "sharded[shards=2,k=16,restarts=auto]:hyperplane"),
    ("3d-4x4x4-hom", (4, 4, 4), [16] * 4,
     "sharded[shards=2,k=8,restarts=auto]:hyperplane"),
]
QUICK_INSTANCES = INSTANCES[:1]

WARM_REPEATS = 20          # warm-serve p50 sample size
IPC_FLOOR = 10.0           # claim (b): >= 10x per-boundary reduction
WARM_FRAC = 0.1            # claim (c): warm p50 <= 0.1x cold
ANYTIME_JMAX = 1.2         # claim (d): J_max <= 1.2x undeadlined
ANYTIME_FRAC = 0.5         # deadline as a fraction of the undeadlined wall


def _problem(dims, sizes):
    return MappingProblem(tuple(dims), Stencil.nearest_neighbor(len(dims)),
                          tuple(sizes))


def run_serve(instances=INSTANCES):
    """One row per instance and claim family; the server is started once
    (2 threads, persistent shard workers) and shared across claims the
    way production traffic would."""
    identity, ipc_rows, warm_rows, anytime_rows = [], [], [], []
    with PlanServer(threads=2, shard_workers=2, max_queue=64) as srv:
        cli = PlanClient(srv)
        for label, dims, sizes, plan in instances:
            problem = _problem(dims, sizes)

            # -- (a) + (c): cold stateless reference vs served ------------
            t0 = time.perf_counter()
            ref = cart_create(dims, node_sizes=sizes, plan=plan,
                              cache=PlanCache())
            t_cold = time.perf_counter() - t0
            t = cli.cart_create_async(dims, node_sizes=sizes, plan=plan)
            served = t.result(timeout=600)
            identity.append({
                "instance": label, "plan": plan,
                "layout_equal": bool(np.array_equal(served.layout,
                                                    ref.layout)),
                "j_max_equal": served.j_max == ref.j_max,
                "j_sum_equal": served.j_sum == ref.j_sum,
                "j_max": served.j_max, "j_sum": served.j_sum,
                "t_cold_s": t_cold, "t_served_cold_s": t.latency_s,
            })

            warm_lat = []
            for _ in range(WARM_REPEATS):
                w = cli.cart_create_async(dims, node_sizes=sizes, plan=plan)
                r = w.result(timeout=60)
                assert r.from_cache, "warm repeat must be a cache hit"
                warm_lat.append(w.latency_s)
            warm_lat.sort()
            warm_rows.append({
                "instance": label, "plan": plan, "t_cold_s": t_cold,
                "warm_p50_s": warm_lat[len(warm_lat) // 2],
                "warm_p95_s": warm_lat[min(len(warm_lat) - 1,
                                           int(0.95 * len(warm_lat)))],
                "repeats": WARM_REPEATS,
                "frac": warm_lat[len(warm_lat) // 2] / t_cold,
            })

            # -- (b): measured per-boundary IPC, stateless vs resident ----
            grid = CartGrid(dims)
            stencil = problem.stencil
            start = get_mapper("hyperplane").assignment(grid, stencil,
                                                        list(sizes))
            stage = parse_plan(plan).stages[-1]
            cfg = dict(stage.refiner.config())
            cfg["backend"] = "serial"       # meter sees identical payloads
            with measure_ipc() as meter:
                stateless = stage.refiner.refine(grid, stencil,
                                                 start.copy(),
                                                 num_nodes=len(sizes))
            with ResidentShardedRefiner(**cfg) as resident_ref:
                resident = resident_ref.refine(grid, stencil, start.copy(),
                                               num_nodes=len(sizes))
            ipc = resident.stats["ipc"]
            stateless_pb = meter.bytes_total / max(1, meter.dispatches)
            ipc_rows.append({
                "instance": label, "plan": plan,
                "identical": bool(np.array_equal(stateless.assignment,
                                                 resident.assignment)),
                "stateless_bytes_total": meter.bytes_total,
                "stateless_dispatches": meter.dispatches,
                "stateless_bytes_per_boundary": stateless_pb,
                "resident_step_bytes": ipc["step_bytes"],
                "resident_boundaries": ipc["boundaries"],
                "resident_bytes_per_boundary":
                    ipc["step_bytes_per_boundary"],
                "resident_init_bytes": ipc["init_bytes"],
                "resident_collect_bytes": ipc["collect_bytes"],
                "reduction": stateless_pb
                    / max(1e-9, ipc["step_bytes_per_boundary"]),
            })

            # -- (d): anytime under a deadline.  Invalidate first: a warm
            # cache would serve the full-quality entry instantly, which is
            # correct serving behavior but wouldn't exercise the cut path
            # this claim is about.
            srv.invalidate(problem)
            deadline_s = max(0.05, ANYTIME_FRAC * t_cold)
            a = cli.cart_create_async(dims, node_sizes=sizes, plan=plan,
                                      deadline_ms=1e3 * deadline_s)
            ar = a.result(timeout=600)
            counts = np.bincount(ar.solution.assignment,
                                 minlength=len(sizes))
            stats = ar.solution.stage_stats[-1]
            anytime_rows.append({
                "instance": label, "plan": plan,
                "deadline_s": deadline_s, "latency_s": a.latency_s,
                "within_deadline": a.latency_s <= deadline_s,
                "cut": a.anytime_cut,
                "cut_stage": stats.get("cut_stage"),
                "cut_at": stats.get("cut_at"),
                "n_temps": stats.get("n_temps"),
                "valid": bool(np.array_equal(np.sort(counts),
                                             np.sort(np.array(sizes)))),
                "j_max": ar.j_max, "j_max_full": ref.j_max,
                "j_max_ratio": ar.j_max / ref.j_max,
            })
        server_stats = srv.stats()
    return {"identity": identity, "ipc": ipc_rows, "warm": warm_rows,
            "anytime": anytime_rows, "server_stats": server_stats}


def validate_serve_claims(out):
    """The PR's acceptance bar, machine-checked (PASS/FAIL verdicts)."""
    claims = []
    bad = [r for r in out["identity"]
           if not (r["layout_equal"] and r["j_max_equal"]
                   and r["j_sum_equal"])]
    claims.append(("PASS" if not bad else "FAIL")
                  + ": persistent-worker serving bit-identical to the "
                  f"stateless sharded engine on all {len(out['identity'])} "
                  "instances (layout, J_max, J_sum)"
                  + (f" (violations: {[r['instance'] for r in bad]})"
                     if bad else ""))
    bad = [r for r in out["ipc"]
           if not r["identical"] or r["reduction"] < IPC_FLOOR]
    claims.append(("PASS" if not bad else "FAIL")
                  + f": measured per-boundary IPC bytes drop >= "
                  f"{IPC_FLOOR:.0f}x vs stateless _block_step on all "
                  f"{len(out['ipc'])} instances (min "
                  f"{min(r['reduction'] for r in out['ipc']):.1f}x)"
                  + (f" (violations: {[(r['instance'], round(r['reduction'], 1)) for r in bad]})"
                     if bad else ""))
    bad = [r for r in out["warm"] if r["frac"] > WARM_FRAC]
    claims.append(("PASS" if not bad else "FAIL")
                  + f": warm served cart_create p50 <= {WARM_FRAC:.1f}x the "
                  f"cold-process solve on all {len(out['warm'])} instances "
                  f"(worst {max(r['frac'] for r in out['warm']):.4f}x)"
                  + (f" (violations: {[(r['instance'], round(r['frac'], 3)) for r in bad]})"
                     if bad else ""))
    bad = [r for r in out["anytime"]
           if not (r["valid"] and r["within_deadline"]
                   and r["j_max_ratio"] <= ANYTIME_JMAX)]
    claims.append(("PASS" if not bad else "FAIL")
                  + ": anytime returns a valid plan within its deadline "
                  f"with J_max <= {ANYTIME_JMAX:.1f}x the undeadlined "
                  f"solve on all {len(out['anytime'])} instances"
                  + (f" (violations: {[(r['instance'], r['valid'], round(r['latency_s'], 3), round(r['deadline_s'], 3), round(r['j_max_ratio'], 3)) for r in bad]})"
                     if bad else ""))
    return claims


def print_serve_table(out):
    print(f"{'instance':18s} {'ident':>5s} {'t_cold':>8s} {'warm_p50':>9s} "
          f"{'frac':>7s} {'ipc_less':>9s} {'ipc_res':>8s} {'redux':>6s} "
          f"{'deadline':>8s} {'latency':>8s} {'cut':>4s} {'Jmax_r':>6s}")
    for ident, w, i, a in zip(out["identity"], out["warm"], out["ipc"],
                              out["anytime"]):
        ok = (ident["layout_equal"] and ident["j_max_equal"]
              and ident["j_sum_equal"])
        print(f"{ident['instance']:18s} {'yes' if ok else 'NO':>5s} "
              f"{w['t_cold_s'] * 1e3:6.0f}ms "
              f"{w['warm_p50_s'] * 1e3:7.1f}ms {w['frac']:7.4f} "
              f"{i['stateless_bytes_per_boundary']:9.0f} "
              f"{i['resident_bytes_per_boundary']:8.0f} "
              f"{i['reduction']:5.1f}x "
              f"{a['deadline_s'] * 1e3:6.0f}ms {a['latency_s'] * 1e3:6.0f}ms "
              f"{'yes' if a['cut'] else 'no':>4s} {a['j_max_ratio']:6.3f}")
    st = out["server_stats"]
    print(f"\nserver: completed={st['completed']} errors={st['errors']} "
          f"rejected={st['rejected']} deadline_misses={st['deadline_misses']} "
          f"anytime_cuts={st['anytime_cuts']} "
          f"cache_hit_rate={st['cache_hit_rate']:.2f} "
          f"p50={st.get('latency_p50_ms', 0):.1f}ms "
          f"p95={st.get('latency_p95_ms', 0):.1f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="first instance only (smoke)")
    ap.add_argument("--json", default=None, help="dump rows + claims")
    args = ap.parse_args()
    out = run_serve(QUICK_INSTANCES if args.quick else INSTANCES)
    print_serve_table(out)
    print()
    claims = validate_serve_claims(out)
    for c in claims:
        print("# " + c)
    out["claims"] = claims
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=float)
    if any(c.startswith("FAIL") for c in claims):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
