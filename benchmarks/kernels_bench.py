"""Kernel microbenchmarks (CPU wall time; TPU perf comes from the roofline).

The Pallas kernels run in interpret mode (correctness path); the jnp oracle
path is the compiled CPU reference — the us_per_call numbers here track
regressions in the *reference* implementations, not TPU speed.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Stencil
from repro.kernels.attention.ops import flash_attention
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.stencil.ops import stencil_apply


def _time(fn, *args, reps=10, **kw):
    fn(*args, **kw).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    st = Stencil.nearest_neighbor(2)
    u = jnp.asarray(rng.standard_normal((514, 514)), jnp.float32)
    w = tuple(0.25 for _ in range(st.k))
    t = _time(stencil_apply, u, st.offsets, w, 1, use_pallas=False)
    rows.append({"name": "kernel_stencil_ref_512", "us_per_call": t * 1e6,
                 "derived": 512 * 512 * st.k * 2 / t / 1e9})  # GFLOP/s

    x = jnp.asarray(rng.standard_normal((8, 512, 1024)), jnp.float32)
    g = jnp.ones((1024,), jnp.float32)
    t = _time(rmsnorm, x, g, use_pallas=False)
    rows.append({"name": "kernel_rmsnorm_ref_8x512x1024",
                 "us_per_call": t * 1e6,
                 "derived": x.size * 4 * 3 / t / 1e9})  # GB/s

    q = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    t = _time(flash_attention, q, k, v, use_pallas=False)
    flops = 4 * 512 * 512 * 4 * 64 / 2
    rows.append({"name": "kernel_flash_ref_s512", "us_per_call": t * 1e6,
                 "derived": flops / t / 1e9})
    return rows
