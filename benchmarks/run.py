"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus the paper-claim
validation verdicts (EXPERIMENTS.md cites this output).

  PYTHONPATH=src python -m benchmarks.run [--fast] [--skip fig8,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="subsample the Fig.8 instance suite")
    ap.add_argument("--skip", default="",
                    help="comma list: fig8,fig67,fig9,roofline,kernels")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from . import (exchange_time, instantiation_time, kernels_bench,
                   reduction_suite, roofline_table)

    claims = []
    suites = []
    if "fig8" not in skip:
        suites.append(("fig8 (reduction suite, 144 instances)",
                       lambda: reduction_suite.run(fast=args.fast),
                       reduction_suite.validate_claims))
    if "fig67" not in skip:
        suites.append(("fig6/7 (exchange-time model)", exchange_time.run,
                       exchange_time.validate_claims))
    if "fig9" not in skip:
        suites.append(("fig9 (instantiation time)", instantiation_time.run,
                       instantiation_time.validate_claims))
    if "roofline" not in skip:
        suites.append(("roofline (from dry-run artifacts)",
                       roofline_table.run, None))
    if "kernels" not in skip:
        suites.append(("kernels (reference micro)", kernels_bench.run, None))

    print("name,us_per_call,derived")
    for title, fn, validate in suites:
        t0 = time.time()
        rows = fn()
        for r in rows:
            extra = ""
            for k in ("dominant", "ci95", "n", "useful_ratio"):
                if k in r:
                    extra += f",{k}={r[k]}"
            print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']:.4f}"
                  + extra)
        sys.stderr.write(f"# {title}: {len(rows)} rows in "
                         f"{time.time() - t0:.1f}s\n")
        if validate:
            claims.extend(validate(rows))
    if claims:
        print("\n# paper-claim validation")
        for c in claims:
            print("# " + c)
        n_fail = sum(c.startswith("FAIL") for c in claims)
        sys.stderr.write(f"# claims: {len(claims) - n_fail}/{len(claims)} "
                         "pass\n")


if __name__ == "__main__":
    main()
