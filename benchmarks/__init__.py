from . import exchange_time, instantiation_time, kernels_bench, reduction_suite, roofline_table  # noqa
