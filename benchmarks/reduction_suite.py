"""Paper Fig. 8: J_sum / J_max reduction over blocked, on the instance suite
I = N x P x D with N = {10,13,...,31}, P = {10,13,...,31} u {32}, D = {2,3}
(|I| = 144).  Machine-independent (paper §VI.C).

Output rows: (figure, stencil, algorithm, metric) -> median reduction +
median mapping time.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (CartGrid, MapperInapplicable, Stencil, dims_create,
                        evaluate, get_mapper)

N_SET = list(range(10, 32, 3))            # 10,13,...,31  (8 values)
P_SET = list(range(10, 32, 3)) + [32]     # 9 values
D_SET = [2, 3]

ALGOS = ["hyperplane", "kdtree", "stencil_strips", "nodecart", "graphgreedy",
         "random"]
STENCILS = {
    "nearest_neighbor": Stencil.nearest_neighbor,
    "nn_with_hops": Stencil.nn_with_hops,
    "component": Stencil.component,
}


def run(fast: bool = False) -> List[Dict]:
    n_set = N_SET[::3] if fast else N_SET
    p_set = P_SET[::3] if fast else P_SET
    rows = []
    reductions: Dict[tuple, list] = {}
    times: Dict[str, list] = {a: [] for a in ALGOS}
    n_instances = 0
    for d in D_SET:
        for N in n_set:
            for ppn in p_set:
                grid = CartGrid(dims_create(N * ppn, d))
                sizes = [ppn] * N
                n_instances += 1
                for sname, ctor in STENCILS.items():
                    stencil = ctor(d)
                    base = get_mapper("blocked").cost(grid, stencil, sizes)
                    for algo in ALGOS:
                        mapper = (get_mapper(algo, max_passes=3)
                                  if algo == "graphgreedy" else get_mapper(algo))
                        t0 = time.perf_counter()
                        try:
                            cost = mapper.cost(grid, stencil, sizes)
                        except MapperInapplicable:
                            continue
                        times[algo].append(time.perf_counter() - t0)
                        for metric, val, b in (("sum", cost.j_sum, base.j_sum),
                                               ("max", cost.j_max, base.j_max)):
                            key = (sname, algo, metric)
                            red = val / b if b else 1.0
                            reductions.setdefault(key, []).append(red)
    for (sname, algo, metric), vals in sorted(reductions.items()):
        arr = np.asarray(vals)
        med = float(np.median(arr))
        # Gaussian-based asymptotic 95% CI of the median (paper's method)
        ci = 1.57 * (np.percentile(arr, 75) - np.percentile(arr, 25)) \
            / max(np.sqrt(len(arr)), 1)
        rows.append({
            "name": f"fig8_{sname}_{algo}_{metric}",
            "us_per_call": np.median(times[algo]) * 1e6 if times[algo] else 0,
            "derived": med,
            "ci95": float(ci),
            "n": len(arr),
        })
    return rows


def validate_claims(rows: List[Dict]) -> List[str]:
    """The paper's §VI.C statistical claims, checked on our data."""
    med = {r["name"]: r["derived"] for r in rows}
    checks = []

    def claim(desc, ok):
        checks.append(("PASS" if ok else "FAIL") + " " + desc)

    for s in ("nearest_neighbor", "nn_with_hops", "component"):
        claim(f"hyperplane beats nodecart on {s} (J_sum)",
              med[f"fig8_{s}_hyperplane_sum"] < med[f"fig8_{s}_nodecart_sum"])
        claim(f"stencil_strips beats nodecart on {s} (J_sum)",
              med[f"fig8_{s}_stencil_strips_sum"] < med[f"fig8_{s}_nodecart_sum"])
    claim("strips ~ VieM-role baseline on nearest_neighbor (within 15%)",
          abs(med["fig8_nearest_neighbor_stencil_strips_sum"] -
              med["fig8_nearest_neighbor_graphgreedy_sum"]) < 0.15)
    claim("random is the worst mapping (J_sum, nearest_neighbor)",
          med["fig8_nearest_neighbor_random_sum"] >
          max(med[f"fig8_nearest_neighbor_{a}_sum"]
              for a in ("hyperplane", "kdtree", "stencil_strips", "nodecart")))
    return checks
