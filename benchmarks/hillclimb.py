import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ before any jax import (same contract as launch/dryrun.py)
"""§Perf hillclimbing driver: run a cell's baseline + named variants, print
the three roofline terms and memory for each, and save the iteration log.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell mixtral-prefill
  PYTHONPATH=src python -m benchmarks.hillclimb --list
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import run_cell

# (name, run_cell kwargs) — each list is one hillclimb with its hypothesis
# log kept in EXPERIMENTS.md §Perf.
CELLS = {
    # worst useful_ratio: GShard einsum dispatch is quadratic in S at 32k
    "mixtral-prefill": dict(
        arch="mixtral-8x7b", shape="prefill_32k", multi=False,
        variants=[
            ("baseline-einsum", {}),
            ("scatter-dispatch", {"moe_dispatch": "scatter"}),
            ("scatter+cap1.0", {"moe_dispatch": "scatter",
                                "overrides": {"capacity_factor": 1.0}}),
            ("scatter+cap+kvshard", {"moe_dispatch": "scatter",
                                     "overrides": {"capacity_factor": 1.0},
                                     "part_rules": {"prefill_kv_constrain": True}}),
        ]),
    # most collective-bound: FSDP gathers x microbatches + EP all-to-all
    "deepseek-train": dict(
        arch="deepseek-v3-671b", shape="train_4k", multi=True,
        variants=[
            ("baseline", {}),
            ("scatter-dispatch", {"moe_dispatch": "scatter"}),
            ("mb4", {"overrides": {"microbatches": 4}}),
            ("mb4+scatter", {"moe_dispatch": "scatter",
                             "overrides": {"microbatches": 4}}),
            ("mb2+scatter", {"moe_dispatch": "scatter",
                             "overrides": {"microbatches": 2}}),
            ("mb2", {"overrides": {"microbatches": 2}}),
            ("mb1", {"overrides": {"microbatches": 1}}),
        ]),
    # collective-bound dense prefill: 56 heads don't divide the model axis
    "yi-prefill": dict(
        arch="yi-34b", shape="prefill_32k", multi=False,
        variants=[
            ("baseline-56h", {}),
            ("pad-heads-64", {"overrides": {"n_heads": 64}}),
            ("pad-heads+mb-na", {"overrides": {"n_heads": 64,
                                               "remat": "dots"}}),
            ("pad-heads+kvshard", {"overrides": {"n_heads": 64},
                                   "part_rules": {"prefill_kv_constrain": True}}),
        ]),
    # long-context decode: ring cache for SWA (memory term)
    "mixtral-long": dict(
        arch="mixtral-8x7b", shape="long_500k", multi=False,
        variants=[
            ("baseline-full-cache", {}),
            ("ring-cache", {"overrides": {"swa_ring_cache": True}}),
        ]),
    "zamba-long": dict(
        arch="zamba2-2.7b", shape="long_500k", multi=False,
        variants=[
            ("baseline-full-cache", {}),
            ("ring-cache", {"overrides": {"swa_ring_cache": True}}),
        ]),
    # SSD chunk-size compute/memory trade (small-d_model ssm)
    "mamba-train": dict(
        arch="mamba2-130m", shape="train_4k", multi=False,
        variants=[
            ("baseline-Q256", {}),
            ("Q128", {"overrides": {"ssm_chunk": 128}}),
            ("Q64", {"overrides": {"ssm_chunk": 64}}),
        ]),
}


def fmt_row(name, r):
    ro = r["roofline"]
    m = r["memory"]
    ops = r.get("coll_wire_by_op", {})
    opstr = " ".join(f"{k.split('-')[-1][:3]}:{v:.2e}"
                     for k, v in sorted(ops.items()))
    return (f"{name:22s} tc={ro['t_compute_s']:9.3e} tm={ro['t_memory_s']:9.3e} "
            f"tx={ro['t_collective_s']:9.3e} dom={ro['dominant']:10s} "
            f"useful={ro['useful_ratio']:5.2f} arg={m['argument_gib']:6.2f}G "
            f"temp={m['temp_gib']:6.2f}G | {opstr}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="runs/perf")
    ap.add_argument("--mappers", default="blocked,stencil_strips",
                    help="comma list; any name get_mapper resolves")
    ap.add_argument("--refine", action="store_true",
                    help="also route collectives over swap-refined layouts "
                         "(core.refine local search on top of each mapper)")
    args = ap.parse_args()
    if args.list or not args.cell:
        print("cells:", ", ".join(CELLS))
        return
    spec = CELLS[args.cell]
    mappers = tuple(args.mappers.split(","))
    if args.refine:
        mappers += tuple(f"refined:{m}" for m in mappers
                         if not m.startswith("refined:"))
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    results = []
    for name, kw in spec["variants"]:
        r = run_cell(spec["arch"], spec["shape"], spec["multi"],
                     mappers=mappers, verbose=False,
                     **kw)
        results.append({"variant": name, **r})
        print(fmt_row(name, r), flush=True)
    (out / f"{args.cell}.json").write_text(
        json.dumps(results, indent=1, default=float))


if __name__ == "__main__":
    main()
