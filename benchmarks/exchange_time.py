"""Paper Fig. 6/7 (+Tables II-VII): neighbor-alltoall exchange time per
message size per algorithm, on the N=50/n=48 and N=100/n=48 instances.

This container has no multi-node network, so times come from the same
alpha-beta machine model the paper's analysis assumes (DESIGN.md §2):

    T(msg) = alpha * k_out
           + max(J_max_node * msg / bw_inter,    (bottleneck node egress)
                 intra_edges_max * msg / bw_intra)  (overlapped on-node path)

with bw_inter = 12.5 GB/s (100 Gb/s NIC, the paper's machines),
bw_intra = 100 GB/s, alpha = 2 us; the shared-memory path progresses
concurrently with the NIC (hence max, not sum).  The derived column is the
speedup over blocked — compare with the paper's reported 3-4x (nearest
neighbor), up to 14x (component).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import CartGrid, MapperInapplicable, Stencil, evaluate, get_mapper

ALPHA = 2e-6
BW_INTER = 12.5e9
BW_INTRA = 100e9

MSG_SIZES = [64, 1024, 16384, 262144, 524288]
INSTANCES = [(50, 48, (50, 48)), (100, 48, (75, 64))]
ALGOS = ["blocked", "hyperplane", "kdtree", "stencil_strips", "nodecart",
         "graphgreedy", "random"]
STENCILS = {
    "nearest_neighbor": Stencil.nearest_neighbor(2),
    "nn_with_hops": Stencil.nn_with_hops(2),
    "component": Stencil.component(2),
}


def _node_stats(grid, stencil, node_of_pos, n_nodes):
    """(max inter-node directed edges per node, max intra edges per node)."""
    inter = np.zeros(n_nodes)
    intra = np.zeros(n_nodes)
    for off in stencil.offsets:
        valid, tgt = grid.shift_ranks(off)
        src_n = node_of_pos
        cross = valid & (src_n != node_of_pos[tgt])
        same = valid & (src_n == node_of_pos[tgt])
        np.add.at(inter, src_n[cross], 1)
        np.add.at(intra, src_n[same], 1)
    return inter.max(), intra.max()


def model_time(j_max_inter: float, intra_max: float, msg: int, k: int) -> float:
    return ALPHA * k + max(j_max_inter * msg / BW_INTER,
                           intra_max * msg / BW_INTRA)


def run() -> List[Dict]:
    rows = []
    for N, ppn, dims in INSTANCES:
        grid = CartGrid(dims)
        sizes = [ppn] * N
        for sname, stencil in STENCILS.items():
            stats = {}
            for algo in ALGOS:
                mapper = (get_mapper(algo, max_passes=3)
                          if algo == "graphgreedy" else get_mapper(algo))
                try:
                    assign = mapper.assignment(grid, stencil, sizes)
                except MapperInapplicable:
                    continue
                stats[algo] = _node_stats(grid, stencil, assign, N)
            for msg in MSG_SIZES:
                t_blocked = model_time(*stats["blocked"], msg, stencil.k)
                for algo, (inter, intra) in stats.items():
                    t = model_time(inter, intra, msg, stencil.k)
                    rows.append({
                        "name": f"fig{6 if N == 50 else 7}_{sname}_{algo}_msg{msg}",
                        "us_per_call": t * 1e6,
                        "derived": t_blocked / t,  # speedup over blocked
                    })
    return rows


def validate_claims(rows: List[Dict]) -> List[str]:
    sp = {r["name"]: r["derived"] for r in rows}
    checks = []

    def claim(desc, ok):
        checks.append(("PASS" if ok else "FAIL") + " " + desc)

    big = 262144
    claim("hyperplane 2-4x over blocked, nn, N=50, large msg",
          2.0 < sp[f"fig6_nearest_neighbor_hyperplane_msg{big}"] < 6.0)
    claim("stencil_strips 2-4x over blocked, nn, N=50, large msg",
          2.0 < sp[f"fig6_nearest_neighbor_stencil_strips_msg{big}"] < 6.0)
    claim("component stencil: strips speedup >= 8x (paper: 10-14x)",
          sp[f"fig6_component_stencil_strips_msg{big}"] >= 8.0)
    claim("mapped beats nodecart on hops (paper: 2-3x faster)",
          sp[f"fig6_nn_with_hops_hyperplane_msg{big}"] >
          1.3 * sp[f"fig6_nn_with_hops_nodecart_msg{big}"])
    claim("random slower than blocked",
          sp[f"fig6_nearest_neighbor_random_msg{big}"] < 1.0)
    return checks
