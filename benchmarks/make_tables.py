"""Build the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run JSON artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables [--dir runs/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = ["mixtral-8x7b", "deepseek-v3-671b", "mamba2-130m", "yi-34b",
              "granite-3-8b", "granite-20b", "qwen3-8b", "zamba2-2.7b",
              "seamless-m4t-medium", "internvl2-76b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, nd=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 1000 or abs(x) < 0.001:
        return f"{x:.2e}"
    return f"{x:.{nd}g}"


def load(d: Path):
    cells = {}
    for f in sorted(d.glob("*.json")):
        if f.name == "summary.json":
            continue
        r = json.loads(f.read_text())
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    # also pick up skip records from summary
    summ = d / "summary.json"
    if summ.exists():
        for r in json.loads(summ.read_text()):
            key = (r["arch"], r["shape"], r["mesh"])
            if key not in cells:
                cells[key] = r
    return cells


def dryrun_table(cells) -> str:
    lines = ["| arch | shape | mesh | chips | compile | arg/dev | temp/dev | collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("single", "multi"):
                r = cells.get((arch, shape, mesh))
                if r is None:
                    continue
                if r.get("status") == "skipped":
                    lines.append(f"| {arch} | {shape} | {mesh} | - | skipped"
                                 f" | - | - | {r.get('reason','')[:46]} |")
                    continue
                if r.get("status") != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | - | ERROR |"
                                 f" - | - | {r.get('error','')[:40]} |")
                    continue
                m = r["memory"]
                cl = ", ".join(f"{k.replace('collective-','c-')}:{v}"
                               for k, v in sorted(r["collectives"].items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {r['chips']} | "
                    f"{r['compile_s']}s | {m['argument_gib']:.2f}GiB | "
                    f"{m['temp_gib']:.2f}GiB | {cl} |")
    return "\n".join(lines)


def roofline_table(cells, mesh="single") -> str:
    lines = ["| arch | shape | t_comp | t_mem | t_coll | dominant | "
             "6ND/HLO | useful | MFU-bound | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None or r.get("status") != "ok":
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt(ro['t_compute_s'])} | "
                f"{fmt(ro['t_memory_s'])} | {fmt(ro['t_collective_s'])} | "
                f"**{ro['dominant']}** | {fmt(ro['useful_ratio_6nd'], 2)} | "
                f"{fmt(ro['useful_ratio'], 2)} | {fmt(ro['mfu_bound'], 2)} | "
                f"{'y' if r['memory']['fits_16gib'] else 'n'} |")
    return "\n".join(lines)


def linksim_table(cells) -> str:
    lines = ["| arch | shape | layout | DCI total | DCI bottleneck-pod | "
             "t_DCI | t_ICI |", "|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, "multi"))
            if r is None or r.get("status") != "ok":
                continue
            for mname, rep in r.get("linksim", {}).items():
                lines.append(
                    f"| {arch} | {shape} | {mname} | "
                    f"{fmt(rep['dci_total_bytes'])} | "
                    f"{fmt(rep['max_dci_pod_bytes'])} | "
                    f"{fmt(rep['t_dci_bottleneck'])} | "
                    f"{fmt(rep['t_ici_bottleneck'])} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--table", default="all",
                    choices=["all", "dryrun", "roofline", "linksim"])
    args = ap.parse_args()
    cells = load(Path(args.dir))
    if args.table in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table(cells))
    if args.table in ("all", "roofline"):
        print("\n### Roofline (single pod, 256 chips)\n")
        print(roofline_table(cells, "single"))
        print("\n### Roofline (multi-pod, 512 chips)\n")
        print(roofline_table(cells, "multi"))
    if args.table in ("all", "linksim"):
        print("\n### Link simulation (multi-pod)\n")
        print(linksim_table(cells))


if __name__ == "__main__":
    main()
