"""Paper Fig. 9: algorithmic runtime to compute the new ranks, on the
largest nearest-neighbor instance (N=100, n=48, grid 75x64), 20 reps each
(paper used 200 on 4800 MPI ranks; we run the full-permutation computation
sequentially — the distributed per-rank forms are benchmarked separately).

Expected (paper): hyperplane ~ kdtree fastest; nodecart ~ +28%;
stencil_strips ~2x slower; VieM-role baseline orders of magnitude slower.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import CartGrid, Stencil, get_mapper
from repro.core.mapping.hyperplane import HyperplaneMapper
from repro.core.mapping.kdtree import KDTreeMapper

REPS = 20
ALGOS = ["blocked", "hyperplane", "kdtree", "stencil_strips", "nodecart",
         "graphgreedy", "random"]


def run() -> List[Dict]:
    grid = CartGrid((75, 64))
    stencil = Stencil.nearest_neighbor(2)
    sizes = [48] * 100
    rows = []
    for algo in ALGOS:
        reps = 3 if algo == "graphgreedy" else REPS
        mapper = (get_mapper(algo, max_passes=3) if algo == "graphgreedy"
                  else get_mapper(algo))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            mapper.coords(grid, stencil, sizes)
            ts.append(time.perf_counter() - t0)
        rows.append({"name": f"fig9_instantiation_{algo}",
                     "us_per_call": float(np.mean(ts) * 1e6),
                     "derived": float(np.mean(ts) /
                                      max(np.mean(ts), 1e-12))})
    # per-rank distributed forms (the paper's O(log N * sum d_i) claim):
    for name, fn in (
            ("hyperplane_per_rank",
             lambda r: HyperplaneMapper.coord_of_rank((75, 64), stencil, 48, r)),
            ("kdtree_per_rank",
             lambda r: KDTreeMapper.coord_of_rank((75, 64), stencil, 0, r))):
        t0 = time.perf_counter()
        for r in range(0, 4800, 48):
            fn(r)
        dt = (time.perf_counter() - t0) / 100
        rows.append({"name": f"fig9_{name}", "us_per_call": dt * 1e6,
                     "derived": 0.0})
    # normalize derived = time relative to hyperplane (paper plots ratios)
    base = next(r["us_per_call"] for r in rows
                if r["name"] == "fig9_instantiation_hyperplane")
    for r in rows:
        r["derived"] = r["us_per_call"] / base
    return rows


def validate_claims(rows: List[Dict]) -> List[str]:
    t = {r["name"]: r["us_per_call"] for r in rows}
    checks = []

    def claim(desc, ok):
        checks.append(("PASS" if ok else "FAIL") + " " + desc)

    claim("VieM-role baseline is >= 20x slower than hyperplane "
          "(paper: >400x for real VieM)",
          t["fig9_instantiation_graphgreedy"] >
          20 * t["fig9_instantiation_hyperplane"])
    # the paper's C implementations put hyperplane ~ kdtree; our numpy
    # vectorization levels differ, so allow 5x (ordering, not constants)
    claim("hyperplane and kdtree within 5x of each other",
          max(t["fig9_instantiation_hyperplane"],
              t["fig9_instantiation_kdtree"]) <
          5 * min(t["fig9_instantiation_hyperplane"],
                  t["fig9_instantiation_kdtree"]))
    claim("stencil_strips slowest of the three new algorithms (paper: 2x)",
          t["fig9_instantiation_stencil_strips"] >
          t["fig9_instantiation_hyperplane"])
    return checks
