"""Base-vs-refined mapper comparison: J_sum, J_max, and wall-time.

For every (grid shape, node layout, stencil) instance, run each applicable
base mapper and its ``refined:<base>`` variant and report the cost drop and
the refinement overhead.  Node layouts include ragged tails (elastic pods
after failures) — the heterogeneous case Nodecart cannot handle but the
refiner improves for free.

  PYTHONPATH=src python -m benchmarks.refine_suite            # full sweep
  PYTHONPATH=src python -m benchmarks.refine_suite --tiny     # smoke (<5 s)
  PYTHONPATH=src python -m benchmarks.refine_suite --json out.json
"""
import argparse
import json
import time

import numpy as np

from repro.core import (CartGrid, MapperInapplicable, Stencil, evaluate,
                        get_mapper)
from repro.core.mapping import MAPPERS

# (label, dims, node_sizes) — ragged tails marked by uneven sizes
INSTANCES = [
    ("2d-48x48-hom", (48, 48), [48] * 48),
    ("2d-50x48-hom", (50, 48), [48] * 50),
    ("2d-16x28-ragged", (16, 28), [256, 192]),
    ("3d-8x8x8-hom", (8, 8, 8), [64] * 8),
    ("3d-12x8x8-ragged", (12, 8, 8), [128] * 5 + [96, 32]),
]
TINY_INSTANCES = [
    ("2d-8x8-hom", (8, 8), [16] * 4),
    ("2d-6x8-ragged", (6, 8), [16, 16, 10, 6]),
    ("3d-4x4x4-hom", (4, 4, 4), [16] * 4),
]

STENCILS = {
    "nn": Stencil.nearest_neighbor,       # 2D 5-point / 3D 7-point
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}


def run(tiny: bool = False, mappers=None, refine_kwargs=None):
    """Returns one row per (instance, stencil, mapper)."""
    instances = TINY_INSTANCES if tiny else INSTANCES
    mappers = mappers or sorted(MAPPERS)
    refine_kwargs = refine_kwargs or {}
    rows = []
    for label, dims, sizes in instances:
        grid = CartGrid(dims)
        for sname, sfn in STENCILS.items():
            stencil = sfn(grid.ndim)
            for mname in mappers:
                try:
                    t0 = time.perf_counter()
                    base_assign = get_mapper(mname).assignment(grid, stencil,
                                                               sizes)
                    t_base = time.perf_counter() - t0
                except MapperInapplicable:
                    continue
                base = evaluate(grid, stencil, base_assign,
                                num_nodes=len(sizes))
                refined_mapper = get_mapper(f"refined:{mname}",
                                            **refine_kwargs)
                t0 = time.perf_counter()
                ref_assign = refined_mapper.assignment(grid, stencil, sizes)
                t_total = time.perf_counter() - t0
                ref = evaluate(grid, stencil, ref_assign,
                               num_nodes=len(sizes))
                rr = refined_mapper.last_result
                rows.append({
                    "instance": label, "stencil": sname, "mapper": mname,
                    "j_sum_base": base.j_sum, "j_sum_refined": ref.j_sum,
                    "j_max_base": base.j_max, "j_max_refined": ref.j_max,
                    "swaps": rr.swaps, "passes": rr.passes,
                    "t_base_s": t_base, "t_refine_s": rr.wall_time_s,
                    "t_total_s": t_total,
                })
    return rows


def validate_claims(rows, objective="j_sum"):
    """Machine-checkable verdicts mirroring benchmarks.run conventions.

    Under the j_max objective the refiner optimizes (J_max, J_sum)
    lexicographically — J_sum alone may grow — so the no-worse claim is
    checked on the metric actually optimized.
    """
    claims = []
    if objective == "j_max":
        worse = [r for r in rows
                 if (r["j_max_refined"], r["j_sum_refined"])
                 > (r["j_max_base"], r["j_sum_base"])]
        label = "refined (J_max, J_sum) <= base"
    else:
        worse = [r for r in rows if r["j_sum_refined"] > r["j_sum_base"]]
        label = "refined J_sum <= base"
    claims.append(("PASS" if not worse else "FAIL")
                  + f": {label} on all {len(rows)} rows"
                  + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                     if worse else ""))
    key = "j_max" if objective == "j_max" else "j_sum"
    improved = [r for r in rows
                if r["mapper"] == "random" and
                r[f"{key}_refined"] < r[f"{key}_base"]]
    total_random = [r for r in rows if r["mapper"] == "random"]
    claims.append(("PASS" if len(improved) == len(total_random) else "FAIL")
                  + f": refinement improves random's {key} on "
                  f"{len(improved)}/{len(total_random)} instances")
    return claims


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="smoke subset")
    ap.add_argument("--mappers", default=None,
                    help="comma list (default: all registered)")
    ap.add_argument("--policy", default="first",
                    choices=["first", "steepest"])
    ap.add_argument("--objective", default="j_sum",
                    choices=["j_sum", "j_max"])
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()

    rows = run(tiny=args.tiny,
               mappers=args.mappers.split(",") if args.mappers else None,
               refine_kwargs={"policy": args.policy,
                              "objective": args.objective})
    hdr = (f"{'instance':18s} {'stencil':8s} {'mapper':16s} "
           f"{'J_sum':>7s} {'->ref':>7s} {'J_max':>6s} {'->ref':>6s} "
           f"{'swaps':>5s} {'t_map':>9s} {'t_ref':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['instance']:18s} {r['stencil']:8s} {r['mapper']:16s} "
              f"{r['j_sum_base']:7.0f} {r['j_sum_refined']:7.0f} "
              f"{r['j_max_base']:6.0f} {r['j_max_refined']:6.0f} "
              f"{r['swaps']:5d} {r['t_base_s']*1e3:7.1f}ms "
              f"{r['t_refine_s']*1e3:7.1f}ms")
    print()
    claims = validate_claims(rows, objective=args.objective)
    for c in claims:
        print("# " + c)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    if any(c.startswith("FAIL") for c in claims):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
