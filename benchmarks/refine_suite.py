"""Base-vs-refined mapper comparison: J_sum, J_max, and wall-time.

For every (grid shape, node layout, stencil) instance, run each applicable
base mapper and its refinement variants (``refined:<base>`` swap local
search, ``refined2:<base>`` alternating j_sum/j_max schedule,
``annealed:<base>`` schedule + simulated-annealing ladder,
``portfolio:<base>`` K batched annealing starts, ``sharded:<base>`` the
portfolio partitioned across worker processes with optional adaptive
restart control) and report the cost drops and the refinement overhead.  Node layouts include ragged tails (elastic
pods after failures) — the heterogeneous case Nodecart cannot handle but
the refiners improve for free.  The ``plan`` stencil rows are
byte-weighted (``launch.mesh.stencil_for_plan``, weights in GiB): for
those, costs and refinement are scored in bytes through the refiners'
``weighted="auto"`` path, alongside the unit-weight rows.

Variant spellings accept bracket options (``portfolio[k=8]``), so the
sweep drives the same name grammar as ``get_mapper``.

  PYTHONPATH=src python -m benchmarks.refine_suite            # full sweep
  PYTHONPATH=src python -m benchmarks.refine_suite --tiny     # smoke (<5 s)
  PYTHONPATH=src python -m benchmarks.refine_suite \
      --variants refined,annealed,portfolio[k=8] --instances ragged
  PYTHONPATH=src python -m benchmarks.refine_suite --tiny --linksim
  PYTHONPATH=src python -m benchmarks.refine_suite --json out.json
  PYTHONPATH=src python -m benchmarks.refine_suite --instances ragged \
      --variants "annealed,portfolio[k=8],sharded[shards=4,k=64,restarts=auto]"
  PYTHONPATH=src python -m benchmarks.refine_suite --device \
      --json results/BENCH_7.json
"""
import argparse
import json
import math
import re
import time

import numpy as np

from repro.core import (CartGrid, MapperInapplicable, Stencil, evaluate,
                        get_mapper)
from repro.core.mapping import MAPPERS

# (label, dims, node_sizes) — ragged tails marked by uneven sizes
INSTANCES = [
    ("2d-48x48-hom", (48, 48), [48] * 48),
    ("2d-50x48-hom", (50, 48), [48] * 50),
    ("2d-16x28-ragged", (16, 28), [256, 192]),
    ("3d-8x8x8-hom", (8, 8, 8), [64] * 8),
    ("3d-12x8x8-ragged", (12, 8, 8), [128] * 5 + [96, 32]),
]
TINY_INSTANCES = [
    ("2d-8x8-hom", (8, 8), [16] * 4),
    ("2d-6x8-ragged", (6, 8), [16, 16, 10, 6]),
    ("3d-4x4x4-hom", (4, 4, 4), [16] * 4),
]


def _plan_stencil(d):
    """Byte-weighted ring stencil of a real (arch, shape) parallelism plan,
    weights rescaled to GiB (an exact power-of-two scale) so tables stay
    readable.  Lazy import: only rows using this stencil pay the jax
    import behind launch.mesh."""
    from repro.configs import get_arch
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import stencil_for_plan
    cfg = get_arch("granite-3-8b")
    shape = ShapeSpec("bench", seq_len=2048, global_batch=16, kind="train")
    st = stencil_for_plan(cfg, shape, multi_pod=(d == 3))
    return Stencil(st.offsets, tuple(w / 2**30 for w in st.weights),
                   name=f"plan-gib-{cfg.name}")


STENCILS = {
    "nn": Stencil.nearest_neighbor,       # 2D 5-point / 3D 7-point
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
    "plan": _plan_stencil,                # byte-weighted (GiB)
}

#: Comparison variants: registry prefix (optionally with bracket options)
#: -> columns.  ScheduledRefiner/PortfolioRefiner own their phase order,
#: so `objective` only applies to the plain `refined` variant.
VARIANTS = ("refined", "refined2", "annealed")


def split_variants(spec):
    """Split a --variants CLI value on commas outside bracket options."""
    from repro.core.mapping import split_mapper_list
    return tuple(split_mapper_list(spec))


def variant_prefix(variant):
    """`portfolio[k=8]` -> `portfolio` (the registry prefix)."""
    return variant.split("[", 1)[0]


def _variant_kwargs(variant, refine_kwargs):
    kwargs = dict(refine_kwargs or {})
    if variant_prefix(variant) != "refined":
        kwargs.pop("objective", None)
    return kwargs


def _linksim_cols(grid, stencil, assign, sizes, suffix, row):
    from repro.analysis.linksim import replay_assignment
    rep = replay_assignment(grid, stencil, assign, sizes,
                            weighted=stencil.is_weighted)
    row[f"dci_max_{suffix}"] = rep.max_dci_pod()
    row[f"dci_total_{suffix}"] = rep.dci_total


def run(tiny: bool = False, mappers=None, variants=VARIANTS,
        refine_kwargs=None, stencils=None, instances=None,
        linksim: bool = False):
    """Returns one row per (instance, stencil, mapper); each row carries
    ``j_sum_<variant>`` / ``j_max_<variant>`` / ``t_<variant>_s`` columns
    (byte-weighted for the ``plan`` stencil rows, with ``weighted=True``
    in the row), plus ``dci_max_*`` replay columns for every row when
    ``linksim`` is set — ragged rows replay on per-pod torus sizes
    (:func:`repro.analysis.linksim.machine_for_nodes`), closing the
    dci==J loop on the elastic path too."""
    instance_rows = TINY_INSTANCES if tiny else INSTANCES
    if instances:
        instance_rows = [r for r in instance_rows if instances in r[0]]
    mappers = mappers or sorted(MAPPERS)
    stencils = stencils or sorted(STENCILS)
    rows = []
    for label, dims, sizes in instance_rows:
        grid = CartGrid(dims)
        for sname in stencils:
            stencil = STENCILS[sname](grid.ndim)
            weighted = stencil.is_weighted
            for mname in mappers:
                try:
                    t0 = time.perf_counter()
                    base_assign = get_mapper(mname).assignment(grid, stencil,
                                                               sizes)
                    t_base = time.perf_counter() - t0
                except MapperInapplicable:
                    continue
                base = evaluate(grid, stencil, base_assign,
                                num_nodes=len(sizes), weighted=weighted)
                ragged = len(set(sizes)) > 1
                row = {
                    "instance": label, "stencil": sname, "mapper": mname,
                    "ragged": ragged, "weighted": weighted,
                    "j_sum_base": base.j_sum, "j_max_base": base.j_max,
                    "t_base_s": t_base,
                }
                if linksim:
                    _linksim_cols(grid, stencil, base_assign, sizes, "base",
                                  row)
                for variant in variants:
                    vm = get_mapper(f"{variant}:{mname}",
                                    **_variant_kwargs(variant, refine_kwargs))
                    t0 = time.perf_counter()
                    v_assign = vm.assignment(grid, stencil, sizes)
                    t_total = time.perf_counter() - t0
                    vc = evaluate(grid, stencil, v_assign,
                                  num_nodes=len(sizes), weighted=weighted)
                    rr = vm.last_result
                    row.update({
                        f"j_sum_{variant}": vc.j_sum,
                        f"j_max_{variant}": vc.j_max,
                        f"swaps_{variant}": rr.swaps,
                        f"t_{variant}_s": rr.wall_time_s,
                        f"t_total_{variant}_s": t_total,
                    })
                    if linksim:
                        _linksim_cols(grid, stencil, v_assign, sizes,
                                      variant, row)
                rows.append(row)
    return rows


def _lex_le(a, b, rtol=0.0):
    """(J_max, J_sum) lexicographic <=, with optional per-component
    relative slack (byte-weighted rows re-evaluate sums in a different
    accumulation order than the refiner's integer-count core, so exact
    float equality is an ulp too strict there).  A genuinely
    lexicographically-<= pair always passes; the slack only rescues pairs
    that lose by ulp-level noise."""
    if a <= b:
        return True
    if math.isclose(a[0], b[0], rel_tol=rtol):
        return a[1] <= b[1] or math.isclose(a[1], b[1], rel_tol=rtol)
    return False


def _key(row, suffix):
    return (row[f"j_max_{suffix}"], row[f"j_sum_{suffix}"])


def _rtol(row):
    return 1e-9 if row.get("weighted") else 0.0


def validate_claims(rows, objective="j_sum", variants=VARIANTS):
    """Machine-checkable verdicts mirroring benchmarks.run conventions.

    ``refined:`` optimizes the configured objective (under j_max it is the
    lexicographic (J_max, J_sum) pair — J_sum alone may grow), so its
    no-worse claim is checked on the metric actually optimized.  The
    scheduled variants select lexicographically by (J_max, J_sum) against
    their own input; ``annealed``/``refined2`` must never exceed
    ``refined:``'s J_max on ragged rows, and ``portfolio`` must be
    lexicographically no worse than ``annealed`` everywhere (its ladder 0
    reproduces the annealed run) at < K x the annealed wall-time on the
    ragged rows (batched ladders, shared schedule prefix).  A ``sharded``
    variant must never worsen (J_max, J_sum) vs ``annealed`` (structural:
    its ladder 0 replays the annealed ladder) and vs ``portfolio`` at
    matching K (bit-identity / adaptive superset); at larger K the claim
    is the K-scaling one — wall-time under 4x the single-process
    portfolio row despite the K_s/K_p-x ladder count.
    """
    claims = []
    if "refined" in variants:
        if objective == "j_max":
            worse = [r for r in rows
                     if not _lex_le(_key(r, "refined"), _key(r, "base"),
                                    _rtol(r))]
            label = "refined (J_max, J_sum) <= base"
        else:
            worse = [r for r in rows if r["j_sum_refined"] > r["j_sum_base"]
                     and not math.isclose(r["j_sum_refined"],
                                          r["j_sum_base"], rel_tol=_rtol(r))]
            label = "refined J_sum <= base"
        claims.append(("PASS" if not worse else "FAIL")
                      + f": {label} on all {len(rows)} rows"
                      + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                         if worse else ""))
        key = "j_max" if objective == "j_max" else "j_sum"
        improved = [r for r in rows
                    if r["mapper"] == "random" and
                    r[f"{key}_refined"] < r[f"{key}_base"]]
        total_random = [r for r in rows if r["mapper"] == "random"]
        claims.append(("PASS" if len(improved) == len(total_random) else "FAIL")
                      + f": refinement improves random's {key} on "
                      f"{len(improved)}/{len(total_random)} instances")
    for variant in variants:
        prefix = variant_prefix(variant)
        if prefix == "refined":
            continue
        worse = [r for r in rows
                 if not _lex_le(_key(r, variant), _key(r, "base"), _rtol(r))]
        claims.append(("PASS" if not worse else "FAIL")
                      + f": {variant} (J_max, J_sum) <= base on all "
                      f"{len(rows)} rows"
                      + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                         if worse else ""))
        # the "no worse than refined:" guarantee only holds when refined:
        # runs the schedule's own first phase (j_sum objective, matching
        # parameters) — under --objective j_max the comparison is apples
        # to oranges, so skip the claim rather than report a false FAIL.
        if "refined" in variants and objective == "j_sum" \
                and prefix not in ("portfolio", "sharded"):
            ragged = [r for r in rows if r["ragged"]]
            worse = [r for r in ragged
                     if r[f"j_max_{variant}"] > r["j_max_refined"]
                     and not math.isclose(r[f"j_max_{variant}"],
                                          r["j_max_refined"],
                                          rel_tol=_rtol(r))]
            claims.append(("PASS" if not worse else "FAIL")
                          + f": {variant} J_max <= refined J_max on all "
                          f"{len(ragged)} ragged-pod rows"
                          + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                             if worse else ""))
    # portfolio vs annealed: dominance + batched wall-time
    port = [v for v in variants if variant_prefix(v) == "portfolio"]
    ann = [v for v in variants if variant_prefix(v) == "annealed"]
    if port and ann:
        pv, av = port[0], ann[0]
        pk = _portfolio_k(pv)
        worse = [r for r in rows
                 if not _lex_le(_key(r, pv), _key(r, av), _rtol(r))]
        claims.append(("PASS" if not worse else "FAIL")
                      + f": {pv} (J_max, J_sum) <= {av} on all {len(rows)} "
                      f"rows"
                      + (f" (violations: {[(r['instance'], r['stencil'], r['mapper']) for r in worse]})"
                         if worse else ""))
        # timing floor: rows whose single ladder finishes in < 0.5 s are
        # all fixed-overhead jitter (both sides are a few hundred numpy
        # calls, and a loaded box can double either), so the
        # batched-not-looped claim is checked where the measurement means
        # something.
        ragged = [r for r in rows if r["ragged"]
                  and r[f"t_{av}_s"] >= 0.5]
        skipped = sum(1 for r in rows if r["ragged"]
                      and r[f"t_{av}_s"] < 0.5)
        slow = [r for r in ragged if r[f"t_{pv}_s"] >= pk * r[f"t_{av}_s"]]
        claims.append(("PASS" if not slow else "FAIL")
                      + f": {pv} wall-time < k={pk} x {av} on all "
                      f"{len(ragged)} ragged-pod rows with {av} >= 0.5s "
                      f"({skipped} sub-0.5s rows skipped)"
                      + (f" (violations: {[(r['instance'], r['stencil'], r['mapper']) for r in slow]})"
                         if slow else ""))
    # sharded engine claims.  Quality: sharded's ladder 0 replays the
    # annealed ladder (through the portfolio engine it is bit-identical
    # to), so `sharded <= annealed` is structural on every row; vs
    # `portfolio` the guarantee is structural only at matching K
    # (bit-identity when adaptive control is off, superset candidates when
    # on) — across different Ks polish-set divergence makes it merely
    # likely, so no claim is stated.  Timing: the K-scaling claim — K_s
    # sharded starts must stay under 4x the K_p single-process row's
    # wall-time despite K_s/K_p-x the ladder count (batched ladders +
    # process sharding) — only means something when K_s > K_p; at equal K
    # sharding is pure overhead at benchmark sizes, so those rows are not
    # compared.
    shard = [v for v in variants if variant_prefix(v) == "sharded"]
    for sv in shard:
        sk = _portfolio_k(sv)
        if ann:
            av = ann[0]
            worse = [r for r in rows
                     if not _lex_le(_key(r, sv), _key(r, av), _rtol(r))]
            claims.append(("PASS" if not worse else "FAIL")
                          + f": {sv} (J_max, J_sum) <= {av} on all "
                          f"{len(rows)} rows"
                          + (f" (violations: {[(r['instance'], r['stencil'], r['mapper']) for r in worse]})"
                             if worse else ""))
        if port:
            pv = port[0]
            pk = _portfolio_k(pv)
            if sk == pk:
                worse = [r for r in rows
                         if not _lex_le(_key(r, sv), _key(r, pv), _rtol(r))]
                claims.append(("PASS" if not worse else "FAIL")
                              + f": {sv} (J_max, J_sum) <= {pv} on all "
                              f"{len(rows)} rows (matching K={sk}: "
                              "bit-identity / adaptive superset)"
                              + (f" (violations: {[(r['instance'], r['stencil'], r['mapper']) for r in worse]})"
                                 if worse else ""))
            else:
                # aggregate, not per-row: single-row wall-times at smoke
                # sizes are dominated by fixed overhead and machine-load
                # jitter, and the sum is what the K-scaling tradeoff is
                # about anyway
                t_s = sum(r[f"t_{sv}_s"] for r in rows)
                t_p = sum(r[f"t_{pv}_s"] for r in rows)
                ok = t_s < 4.0 * t_p
                claims.append(("PASS" if ok else "FAIL")
                              + f": {sv} (K={sk}) total wall-time "
                              f"{t_s:.1f}s < 4x {pv} (K={pk}) total "
                              f"{t_p:.1f}s over {len(rows)} rows "
                              f"({sk / pk:.0f}x the starts at "
                              f"{t_s / max(t_p, 1e-9):.1f}x the time)")
    # linksim replay: simulated bottleneck DCI must track J_max exactly
    sim_rows = [r for r in rows if "dci_max_base" in r]
    if sim_rows:
        bad = []
        for r in sim_rows:
            for suffix in ("base",) + tuple(variants):
                if f"dci_max_{suffix}" not in r:
                    continue
                if not math.isclose(r[f"dci_max_{suffix}"],
                                    r[f"j_max_{suffix}"],
                                    rel_tol=1e-9, abs_tol=1e-9):
                    bad.append((r["instance"], r["mapper"], suffix))
        n_ragged = sum(1 for r in sim_rows if r["ragged"])
        claims.append(("PASS" if not bad else "FAIL")
                      + f": linksim max_dci_pod == J_max on all "
                      f"{len(sim_rows)} rows ({n_ragged} ragged, replayed "
                      f"on per-pod torus sizes)"
                      + (f" (violations: {bad})" if bad else ""))
    return claims


# ---------------------------------------------------------------------------
# warm-start repair suite: repair-vs-cold on the churn scenarios
# (BENCH_6.json — wall-time, J_max/J_sum, repair-vs-cold ratios)

REPAIR_EPS = 0.05           # quality band vs the cold elastic portfolio
REPAIR_LATENCY_FRAC = 0.5   # repair wall-time cap as a fraction of cold


def _repair_stencil():
    """Byte-weighted ring (data-parallel traffic outweighing model-parallel
    — the ``stencil_for_plan`` shape) so the quality band is measured at
    the weighted granularity the runtime actually solves."""
    return Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
                   (3.0, 3.0, 1.0, 1.0), name="ring-w")


def repair_scenarios():
    """(label, prev_shape, prev_sizes, shape, sizes, node_map) — the three
    churn kinds the runtime produces: whole-pod loss (runtime-style
    ``(n, chips)`` re-mesh), pod rejoin, and a slow pod's down-weighted
    re-solve."""
    from repro.core.repair import downweighted_node_sizes
    return [
        ("loss-8to7", (8, 16), (16,) * 8, (7, 16), (16,) * 7,
         [0, 1, 2, 3, 4, 5, 7]),
        ("add-7to8", (7, 16), (16,) * 7, (8, 16), (16,) * 8,
         [0, 1, 2, 3, 4, 5, 6, -1]),
        ("slow-8", (8, 16), (16,) * 8, (8, 16),
         tuple(downweighted_node_sizes((16,) * 8, 3, 2.0)), None),
    ]


def run_repair():
    """One row per churn scenario: cold elastic-portfolio solve vs
    warm-start repair of the pre-churn solution (quality, wall-time,
    ratios, and the repair stage's own stats)."""
    from repro.core import (MappingProblem, elastic_portfolio_plan,
                            repair_layout)
    st = _repair_stencil()
    rows = []
    for label, pshape, psizes, shape, sizes, node_map in repair_scenarios():
        prev = elastic_portfolio_plan().solve(
            MappingProblem(tuple(pshape), st, tuple(psizes)))
        t0 = time.perf_counter()
        cold = elastic_portfolio_plan().solve(
            MappingProblem(tuple(shape), st, tuple(sizes)))
        t_cold = time.perf_counter() - t0
        rep, t_rep = None, float("inf")
        for _ in range(2):      # min-of-2: repair is deterministic, the
            t0 = time.perf_counter()    # clock is the only noisy part
            rep = repair_layout(prev, sizes, mesh_shape=shape,
                                node_map=node_map, cache=False)
            t_rep = min(t_rep, time.perf_counter() - t0)
        stats = rep.stage_stats[0]
        rows.append({
            "scenario": label,
            "prev_shape": list(pshape), "mesh_shape": list(shape),
            "node_sizes": [int(s) for s in sizes],
            "j_max_cold": cold.j_max, "j_sum_cold": cold.j_sum,
            "t_cold_s": t_cold,
            "j_max_repair": rep.j_max, "j_sum_repair": rep.j_sum,
            "t_repair_s": t_rep,
            "ratio_j_max": rep.j_max / cold.j_max,
            "ratio_j_sum": rep.j_sum / cold.j_sum,
            "latency_frac": t_rep / t_cold,
            "used_fallback": bool(stats.get("used_fallback")),
            "strategy": stats.get("strategy", "warm"),
            "swaps": stats.get("swaps"),
            "resplits": stats.get("resplits"),
            "pinned": stats.get("pinned"),
        })
    return rows


def validate_repair_claims(rows, eps=REPAIR_EPS, frac=REPAIR_LATENCY_FRAC):
    """The PR's acceptance bar, machine-checked: repair within ``eps`` of
    cold on both objectives, at most ``frac`` of cold's wall-time, and
    never via the silent cold fallback."""
    claims = []
    bad = [r for r in rows if r["ratio_j_max"] > 1 + eps
           or r["ratio_j_sum"] > 1 + eps]
    claims.append(("PASS" if not bad else "FAIL")
                  + f": repair within {eps:.0%} of cold (J_max and J_sum) "
                  f"on all {len(rows)} scenarios"
                  + (f" (violations: {[(r['scenario'], round(r['ratio_j_max'], 3), round(r['ratio_j_sum'], 3)) for r in bad]})"
                     if bad else ""))
    slow = [r for r in rows if r["latency_frac"] > frac]
    claims.append(("PASS" if not slow else "FAIL")
                  + f": repair wall-time <= {frac:.0%} of cold on all "
                  f"{len(rows)} scenarios"
                  + (f" (violations: {[(r['scenario'], round(r['latency_frac'], 2)) for r in slow]})"
                     if slow else ""))
    fb = [r for r in rows if r["used_fallback"]]
    claims.append(("PASS" if not fb else "FAIL")
                  + ": warm path taken on all scenarios (no cold fallback)"
                  + (f" (violations: {[r['scenario'] for r in fb]})"
                     if fb else ""))
    return claims


def print_repair_table(rows):
    print(f"{'scenario':12s} {'mesh':10s} "
          f"{'Jmax_cold':>9s} {'Jsum_cold':>9s} "
          f"{'Jmax_rep':>9s} {'Jsum_rep':>9s} "
          f"{'rmax':>6s} {'rsum':>6s} {'t_cold':>8s} {'t_rep':>8s} "
          f"{'frac':>5s}  strategy")
    for r in rows:
        shape = "x".join(str(d) for d in r["mesh_shape"])
        print(f"{r['scenario']:12s} {shape:10s} "
              f"{r['j_max_cold']:9.0f} {r['j_sum_cold']:9.0f} "
              f"{r['j_max_repair']:9.0f} {r['j_sum_repair']:9.0f} "
              f"{r['ratio_j_max']:6.3f} {r['ratio_j_sum']:6.3f} "
              f"{r['t_cold_s'] * 1e3:6.0f}ms {r['t_repair_s'] * 1e3:6.0f}ms "
              f"{r['latency_frac']:5.2f}  {r['strategy']}")


# ---------------------------------------------------------------------------
# device-resident portfolio suite: dominance at equal proposal budget +
# the K-scaling sweep (BENCH_7.json — J_max/J_sum vs the serial portfolio,
# starts-per-second at fixed budget)

#: Dominance config: both engines get the same K, schedule, and proposal
#: budget; the device's edge is structural (2K candidates incl. per-ladder
#: walk minima, polish over every unique survivor vs the host's top-3).
DEVICE_K = 32
DEVICE_MOVES = 40
DEVICE_BASES = ("hyperplane", "kdtree", "blocked", "random")
#: K-scaling sweep: ladder count at a fixed total proposal budget per
#: temperature (K x sa_moves held constant) — the paper's "more starts at
#: the same budget" lever, which only pays off if batching amortizes.
DEVICE_SWEEP_KS = (8, 64, 256, 1024)
DEVICE_SWEEP_BUDGET = 25600


def run_device():
    """Dominance rows: tiny refine-suite instances x base mappers,
    ``device[k=K,sa_moves=M,polish_top=none]:<base>`` against
    ``portfolio[k=K,sa_moves=M]:<base>`` at equal proposal budget
    (the pinned claim of ``tests/test_device_portfolio.py``, here over
    the full base-mapper matrix)."""
    spell_d = f"device[k={DEVICE_K},sa_moves={DEVICE_MOVES},polish_top=none]"
    spell_p = f"portfolio[k={DEVICE_K},sa_moves={DEVICE_MOVES}]"
    rows = []
    for label, dims, sizes in TINY_INSTANCES:
        grid = CartGrid(dims)
        stencil = Stencil.nearest_neighbor(grid.ndim)
        for base in DEVICE_BASES:
            row = {"instance": label, "base": base,
                   "k": DEVICE_K, "sa_moves": DEVICE_MOVES}
            for tag, spell in (("device", spell_d), ("portfolio", spell_p)):
                vm = get_mapper(f"{spell}:{base}")
                t0 = time.perf_counter()
                assign = vm.assignment(grid, stencil, sizes)
                t_total = time.perf_counter() - t0
                cost = evaluate(grid, stencil, assign, num_nodes=len(sizes))
                row[f"j_max_{tag}"] = cost.j_max
                row[f"j_sum_{tag}"] = cost.j_sum
                row[f"t_{tag}_s"] = t_total
                if tag == "device":
                    row["backend"] = vm.last_result.stats["backend"]
            rows.append(row)
    return rows


def run_device_sweep(ks=DEVICE_SWEEP_KS, budget=DEVICE_SWEEP_BUDGET):
    """One full temperature per K at a fixed proposal budget (jit warmed,
    min-of-3): wall-time, starts/s, proposals/s.  The lock-step vmapped
    kernel makes per-proposal cost roughly K-independent, so K=1024 must
    land under 4x the K=8 wall-time — more starts for the same budget."""
    from repro.core.refine import DeviceLadderEngine
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    rng = np.random.default_rng(5)
    start = rng.permutation(np.repeat(np.arange(4), grid.size // 4))
    sweep = []
    for K in ks:
        moves = budget // K
        eng = DeviceLadderEngine(grid, stencil, start,
                                 seeds=tuple(range(K)), num_nodes=4)
        alive = np.ones(K, dtype=bool)
        temps, eps = np.full(K, 1.0), np.full(K, 1e-2)
        eng.run_temperature(temps, moves, alive, eps)        # jit compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            eng.run_temperature(temps, moves, alive, eps)
            best = min(best, time.perf_counter() - t0)
        sweep.append({"k": K, "sa_moves": moves, "proposals": K * moves,
                      "t_temp_s": best, "starts_per_s": K / best,
                      "proposals_per_s": K * moves / best})
    return sweep


def validate_device_claims(rows, sweep):
    """The PR's acceptance bar, machine-checked: device lexicographically
    never worse than the serial portfolio at equal budget on every row, no
    silent host fallback, and K=1024 under 4x the K=8 wall-time at fixed
    proposal budget."""
    claims = []
    worse = [r for r in rows
             if not _lex_le((r["j_max_device"], r["j_sum_device"]),
                            (r["j_max_portfolio"], r["j_sum_portfolio"]))]
    claims.append(("PASS" if not worse else "FAIL")
                  + f": device[k={DEVICE_K}] (J_max, J_sum) <= "
                  f"portfolio[k={DEVICE_K}] at equal proposal budget on all "
                  f"{len(rows)} rows"
                  + (f" (violations: {[(r['instance'], r['base']) for r in worse]})"
                     if worse else ""))
    fb = [r for r in rows if not r["backend"].startswith("device[")]
    claims.append(("PASS" if not fb else "FAIL")
                  + ": device path taken on all rows (no host fallback)"
                  + (f" (violations: {[(r['instance'], r['base'], r['backend']) for r in fb]})"
                     if fb else ""))
    t = {s["k"]: s["t_temp_s"] for s in sweep}
    lo, hi = min(t), max(t)
    ok = t[hi] < 4.0 * t[lo]
    claims.append(("PASS" if ok else "FAIL")
                  + f": K={hi} wall-time {t[hi] * 1e3:.0f}ms < 4x K={lo} "
                  f"({t[lo] * 1e3:.0f}ms) at {DEVICE_SWEEP_BUDGET} "
                  f"proposals/temperature ({hi // lo}x the starts at "
                  f"{t[hi] / t[lo]:.2f}x the time)")
    return claims


def print_device_table(rows, sweep):
    print(f"{'instance':14s} {'base':12s} "
          f"{'Jmax_dev':>8s} {'Jsum_dev':>8s} "
          f"{'Jmax_port':>9s} {'Jsum_port':>9s} "
          f"{'t_dev':>8s} {'t_port':>8s}  backend")
    for r in rows:
        print(f"{r['instance']:14s} {r['base']:12s} "
              f"{r['j_max_device']:8.0f} {r['j_sum_device']:8.0f} "
              f"{r['j_max_portfolio']:9.0f} {r['j_sum_portfolio']:9.0f} "
              f"{r['t_device_s'] * 1e3:6.0f}ms {r['t_portfolio_s'] * 1e3:6.0f}ms"
              f"  {r['backend']}")
    print()
    print(f"{'K':>5s} {'moves':>6s} {'proposals':>9s} {'t_temp':>8s} "
          f"{'starts/s':>9s} {'props/s':>10s}")
    for s in sweep:
        print(f"{s['k']:5d} {s['sa_moves']:6d} {s['proposals']:9d} "
              f"{s['t_temp_s'] * 1e3:6.0f}ms {s['starts_per_s']:9.0f} "
              f"{s['proposals_per_s']:10.0f}")


# ---------------------------------------------------------------------------
# hierarchical mapping suite (BENCH_8.json): multilevel quality at a
# fraction of the flat portfolio's cost on a deep 4096-chip machine, plus
# the depth sweep against the blocked baseline.

#: claim (a) instance: a 2-level machine of 256 pods x 16 chips (the
#: V5E_4RACK shape scaled out), 64x64 process grid.
HIER_BIG = ("2d-64x64-4096chips", (64, 64), [16] * 256, "16x16")
HIER_FLAT_SPELL = "portfolio[k=8]:hyperplane"
HIER_BIG_SPELL = "hier[fanouts=16x16]:hyperplane"
#: claim (a) bars: hier within 5% of the flat portfolio's J_max at <= 25%
#: of its wall-time.
HIER_JMAX_RATIO = 1.05
HIER_TIME_FRAC = 0.25
#: claim (b) instance + sweep: every tree depth must strictly beat the
#: blocked baseline on J_sum.
HIER_SWEEP = ("2d-32x32-1024chips", (32, 32), [16] * 64)
HIER_SWEEP_DEPTHS = (2, 3, 4)
HIER_SWEEP_SOLVER = "portfolio[k=4]"


def _hier_cold(spell, grid, stencil, sizes):
    """One cold solve: the subtree cache is cleared first so reported
    wall-times never ride on hits warmed by a previous variant."""
    from repro.core.refine import hier_subtree_cache
    hier_subtree_cache().clear()
    vm = get_mapper(spell)
    t0 = time.perf_counter()
    assign = vm.assignment(grid, stencil, sizes)
    t = time.perf_counter() - t0
    cost = evaluate(grid, stencil, assign, num_nodes=len(sizes))
    return assign, cost, t, vm


def run_hier_big():
    """Claim (a) rows: blocked / flat portfolio / hier on the 4096-chip
    instance, plus a warm hier re-solve (pure subtree-cache hits) to
    report the elastic re-mesh latency."""
    label, dims, sizes, fanouts = HIER_BIG
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    rows = []
    for tag, spell in (("blocked", "blocked"),
                       ("flat", HIER_FLAT_SPELL),
                       ("hier", HIER_BIG_SPELL)):
        _, cost, t, vm = _hier_cold(spell, grid, stencil, sizes)
        row = {"instance": label, "variant": tag, "spelling": spell,
               "j_max": cost.j_max, "j_sum": cost.j_sum, "t_s": t}
        if tag == "hier":
            stats = vm.last_result.stats
            row["solves"] = stats["solves"]
            row["fanouts"] = fanouts
            t0 = time.perf_counter()
            get_mapper(spell).assignment(grid, stencil, sizes)
            row["t_warm_s"] = time.perf_counter() - t0
        rows.append(row)
    return rows


def run_hier_sweep():
    """Claim (b) rows: ``hier[depth=d,solver=...]:blocked`` vs flat
    blocked at every tree depth."""
    label, dims, sizes = HIER_SWEEP
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    blocked = get_mapper("blocked").assignment(grid, stencil, sizes)
    ref = evaluate(grid, stencil, blocked, num_nodes=len(sizes))
    rows = []
    for depth in HIER_SWEEP_DEPTHS:
        spell = f"hier[depth={depth},solver={HIER_SWEEP_SOLVER}]:blocked"
        _, cost, t, _ = _hier_cold(spell, grid, stencil, sizes)
        rows.append({"instance": label, "depth": depth, "spelling": spell,
                     "j_max": cost.j_max, "j_sum": cost.j_sum, "t_s": t,
                     "j_max_blocked": ref.j_max, "j_sum_blocked": ref.j_sum})
    return rows


def validate_hier_claims(big, sweep):
    claims = []
    by = {r["variant"]: r for r in big}
    h, f = by["hier"], by["flat"]
    r_jmax = h["j_max"] / f["j_max"]
    r_time = h["t_s"] / f["t_s"]
    ok = r_jmax <= HIER_JMAX_RATIO and r_time <= HIER_TIME_FRAC
    claims.append(("PASS" if ok else "FAIL")
                  + f": {HIER_BIG_SPELL} reaches J_max <= "
                  f"{HIER_JMAX_RATIO:.2f}x of {HIER_FLAT_SPELL} at <= "
                  f"{HIER_TIME_FRAC:.0%} of its wall-time on "
                  f"{HIER_BIG[0]} (J_max ratio {r_jmax:.3f}, "
                  f"time ratio {r_time:.3f})")
    bad = [r for r in sweep if not r["j_sum"] < r["j_sum_blocked"]]
    claims.append(("PASS" if not bad else "FAIL")
                  + f": hier strictly beats flat blocked on J_sum at every "
                  f"depth in {list(HIER_SWEEP_DEPTHS)} on {HIER_SWEEP[0]}"
                  + (f" (violations: {[(r['depth'], r['j_sum']) for r in bad]})"
                     if bad else ""))
    return claims


def print_hier_table(big, sweep):
    print(f"{'variant':8s} {'spelling':42s} {'J_max':>6s} {'J_sum':>7s} "
          f"{'t':>8s} {'t_warm':>8s}")
    for r in big:
        warm = f"{r['t_warm_s']:7.2f}s" if "t_warm_s" in r else f"{'-':>8s}"
        print(f"{r['variant']:8s} {r['spelling']:42s} {r['j_max']:6.0f} "
              f"{r['j_sum']:7.0f} {r['t_s']:7.2f}s {warm}")
    print()
    print(f"{'depth':5s} {'spelling':42s} {'J_max':>6s} {'J_sum':>7s} "
          f"{'Jsum_blk':>8s} {'t':>8s}")
    for r in sweep:
        print(f"{r['depth']:<5d} {r['spelling']:42s} {r['j_max']:6.0f} "
              f"{r['j_sum']:7.0f} {r['j_sum_blocked']:8.0f} "
              f"{r['t_s']:7.2f}s")


def _portfolio_k(variant):
    m = re.search(r"\bk=(\d+)", variant)
    if m:
        return int(m.group(1))
    from repro.core import PortfolioRefiner
    return PortfolioRefiner().k


_SHORT = {"refined": "ref", "refined2": "ref2", "annealed": "ann",
          "portfolio": "port", "sharded": "shrd"}


def _short(variant):
    return _SHORT.get(variant_prefix(variant), variant_prefix(variant)[:4])


def print_table(rows, variants=VARIANTS):
    short = [_short(v) for v in variants]
    cols = "".join(f" {'Jsum_' + s:>9s} {'Jmax_' + s:>9s}" for s in short)
    times = "".join(f" {'t_' + s:>9s}" for s in short)
    print(f"{'instance':18s} {'stencil':8s} {'mapper':15s} "
          f"{'J_sum':>9s} {'J_max':>7s}{cols}{times}")
    for r in rows:
        v_cols = "".join(f" {r[f'j_sum_{v}']:9.0f} {r[f'j_max_{v}']:9.0f}"
                         for v in variants)
        v_times = "".join(f" {r[f't_{v}_s'] * 1e3:7.1f}ms" for v in variants)
        print(f"{r['instance']:18s} {r['stencil']:8s} {r['mapper']:15s} "
              f"{r['j_sum_base']:9.0f} {r['j_max_base']:7.0f}"
              f"{v_cols}{v_times}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="smoke subset")
    ap.add_argument("--mappers", default=None,
                    help="comma list (default: all registered)")
    ap.add_argument("--variants", default=",".join(VARIANTS),
                    help="comma list of refinement prefixes to compare "
                         "(bracket options allowed, e.g. portfolio[k=8])")
    ap.add_argument("--stencils", default=None,
                    help="comma list of stencil keys "
                         f"(default: all of {sorted(STENCILS)})")
    ap.add_argument("--instances", default=None,
                    help="substring filter on instance labels "
                         "(e.g. 'ragged')")
    ap.add_argument("--linksim", action="store_true",
                    help="replay every row through analysis.linksim (ragged "
                         "rows on per-pod torus sizes) and add dci_max "
                         "columns + the J_max==dci claim")
    ap.add_argument("--policy", default="first",
                    choices=["first", "steepest"])
    ap.add_argument("--objective", default="j_sum",
                    choices=["j_sum", "j_max"],
                    help="refined: objective (scheduled variants own theirs)")
    ap.add_argument("--repair", action="store_true",
                    help="run the warm-start repair suite instead of the "
                         "variant sweep (repair-vs-cold on loss/add/slow "
                         "churn scenarios; --json emits the BENCH_6.json "
                         "rows)")
    ap.add_argument("--device", action="store_true",
                    help="run the device-portfolio suite instead of the "
                         "variant sweep (dominance vs the serial portfolio "
                         "at equal proposal budget + the K-scaling sweep; "
                         "--json emits the BENCH_7.json payload)")
    ap.add_argument("--hier", action="store_true",
                    help="run the hierarchical mapping suite instead of the "
                         "variant sweep (hier-vs-flat-portfolio on a "
                         "4096-chip 2-level machine + the depth sweep vs "
                         "blocked; --json emits the BENCH_8.json payload)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()

    if args.hier:
        big = run_hier_big()
        sweep = run_hier_sweep()
        print_hier_table(big, sweep)
        print()
        claims = validate_hier_claims(big, sweep)
        for c in claims:
            print("# " + c)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"big": big, "depth_sweep": sweep,
                           "claims": claims}, f, indent=1, default=float)
        if any(c.startswith("FAIL") for c in claims):
            raise SystemExit(1)
        return

    if args.device:
        from repro.core.refine import jax_ready
        if not jax_ready():
            raise SystemExit("--device needs jax (device engine backend)")
        rows = run_device()
        sweep = run_device_sweep()
        print_device_table(rows, sweep)
        print()
        claims = validate_device_claims(rows, sweep)
        for c in claims:
            print("# " + c)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"dominance": rows, "k_scaling": sweep,
                           "claims": claims}, f, indent=1, default=float)
        if any(c.startswith("FAIL") for c in claims):
            raise SystemExit(1)
        return

    if args.repair:
        rows = run_repair()
        print_repair_table(rows)
        print()
        claims = validate_repair_claims(rows)
        for c in claims:
            print("# " + c)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1, default=float)
        if any(c.startswith("FAIL") for c in claims):
            raise SystemExit(1)
        return

    variants = split_variants(args.variants)
    rows = run(tiny=args.tiny,
               mappers=args.mappers.split(",") if args.mappers else None,
               variants=variants,
               stencils=args.stencils.split(",") if args.stencils else None,
               instances=args.instances,
               linksim=args.linksim,
               refine_kwargs={"policy": args.policy,
                              "objective": args.objective})
    print_table(rows, variants=variants)
    print()
    claims = validate_claims(rows, objective=args.objective,
                             variants=variants)
    for c in claims:
        print("# " + c)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    if any(c.startswith("FAIL") for c in claims):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
