"""Base-vs-refined mapper comparison: J_sum, J_max, and wall-time.

For every (grid shape, node layout, stencil) instance, run each applicable
base mapper and its refinement variants (``refined:<base>`` swap local
search, ``refined2:<base>`` alternating j_sum/j_max schedule,
``annealed:<base>`` schedule + simulated-annealing ladder) and report the
cost drops and the refinement overhead.  Node layouts include ragged tails
(elastic pods after failures) — the heterogeneous case Nodecart cannot
handle but the refiners improve for free.

  PYTHONPATH=src python -m benchmarks.refine_suite            # full sweep
  PYTHONPATH=src python -m benchmarks.refine_suite --tiny     # smoke (<5 s)
  PYTHONPATH=src python -m benchmarks.refine_suite --variants refined,annealed
  PYTHONPATH=src python -m benchmarks.refine_suite --json out.json
"""
import argparse
import json
import time

import numpy as np

from repro.core import (CartGrid, MapperInapplicable, Stencil, evaluate,
                        get_mapper)
from repro.core.mapping import MAPPERS

# (label, dims, node_sizes) — ragged tails marked by uneven sizes
INSTANCES = [
    ("2d-48x48-hom", (48, 48), [48] * 48),
    ("2d-50x48-hom", (50, 48), [48] * 50),
    ("2d-16x28-ragged", (16, 28), [256, 192]),
    ("3d-8x8x8-hom", (8, 8, 8), [64] * 8),
    ("3d-12x8x8-ragged", (12, 8, 8), [128] * 5 + [96, 32]),
]
TINY_INSTANCES = [
    ("2d-8x8-hom", (8, 8), [16] * 4),
    ("2d-6x8-ragged", (6, 8), [16, 16, 10, 6]),
    ("3d-4x4x4-hom", (4, 4, 4), [16] * 4),
]

STENCILS = {
    "nn": Stencil.nearest_neighbor,       # 2D 5-point / 3D 7-point
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}

#: Comparison variants: registry prefix -> kwargs filter (ScheduledRefiner
#: has no single `objective`; it owns its phase order).
VARIANTS = ("refined", "refined2", "annealed")


def _variant_kwargs(variant, refine_kwargs):
    kwargs = dict(refine_kwargs or {})
    if variant != "refined":
        kwargs.pop("objective", None)
    return kwargs


def run(tiny: bool = False, mappers=None, variants=VARIANTS,
        refine_kwargs=None):
    """Returns one row per (instance, stencil, mapper); each row carries
    ``j_sum_<variant>`` / ``j_max_<variant>`` / ``t_<variant>_s`` columns."""
    instances = TINY_INSTANCES if tiny else INSTANCES
    mappers = mappers or sorted(MAPPERS)
    rows = []
    for label, dims, sizes in instances:
        grid = CartGrid(dims)
        for sname, sfn in STENCILS.items():
            stencil = sfn(grid.ndim)
            for mname in mappers:
                try:
                    t0 = time.perf_counter()
                    base_assign = get_mapper(mname).assignment(grid, stencil,
                                                               sizes)
                    t_base = time.perf_counter() - t0
                except MapperInapplicable:
                    continue
                base = evaluate(grid, stencil, base_assign,
                                num_nodes=len(sizes))
                row = {
                    "instance": label, "stencil": sname, "mapper": mname,
                    "ragged": len(set(sizes)) > 1,
                    "j_sum_base": base.j_sum, "j_max_base": base.j_max,
                    "t_base_s": t_base,
                }
                for variant in variants:
                    vm = get_mapper(f"{variant}:{mname}",
                                    **_variant_kwargs(variant, refine_kwargs))
                    t0 = time.perf_counter()
                    v_assign = vm.assignment(grid, stencil, sizes)
                    t_total = time.perf_counter() - t0
                    vc = evaluate(grid, stencil, v_assign,
                                  num_nodes=len(sizes))
                    rr = vm.last_result
                    row.update({
                        f"j_sum_{variant}": vc.j_sum,
                        f"j_max_{variant}": vc.j_max,
                        f"swaps_{variant}": rr.swaps,
                        f"t_{variant}_s": rr.wall_time_s,
                        f"t_total_{variant}_s": t_total,
                    })
                rows.append(row)
    return rows


def validate_claims(rows, objective="j_sum", variants=VARIANTS):
    """Machine-checkable verdicts mirroring benchmarks.run conventions.

    ``refined:`` optimizes the configured objective (under j_max it is the
    lexicographic (J_max, J_sum) pair — J_sum alone may grow), so its
    no-worse claim is checked on the metric actually optimized.  The
    scheduled variants select lexicographically by (J_max, J_sum) against
    their own input, and ``annealed``/``refined2`` must never exceed
    ``refined:``'s J_max (bottleneck-relief acceptance, checked on the
    ragged elastic-pod cases).
    """
    claims = []
    if "refined" in variants:
        if objective == "j_max":
            worse = [r for r in rows
                     if (r["j_max_refined"], r["j_sum_refined"])
                     > (r["j_max_base"], r["j_sum_base"])]
            label = "refined (J_max, J_sum) <= base"
        else:
            worse = [r for r in rows if r["j_sum_refined"] > r["j_sum_base"]]
            label = "refined J_sum <= base"
        claims.append(("PASS" if not worse else "FAIL")
                      + f": {label} on all {len(rows)} rows"
                      + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                         if worse else ""))
        key = "j_max" if objective == "j_max" else "j_sum"
        improved = [r for r in rows
                    if r["mapper"] == "random" and
                    r[f"{key}_refined"] < r[f"{key}_base"]]
        total_random = [r for r in rows if r["mapper"] == "random"]
        claims.append(("PASS" if len(improved) == len(total_random) else "FAIL")
                      + f": refinement improves random's {key} on "
                      f"{len(improved)}/{len(total_random)} instances")
    for variant in variants:
        if variant == "refined":
            continue
        worse = [r for r in rows
                 if (r[f"j_max_{variant}"], r[f"j_sum_{variant}"])
                 > (r["j_max_base"], r["j_sum_base"])]
        claims.append(("PASS" if not worse else "FAIL")
                      + f": {variant} (J_max, J_sum) <= base on all "
                      f"{len(rows)} rows"
                      + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                         if worse else ""))
        # the "no worse than refined:" guarantee only holds when refined:
        # runs the schedule's own first phase (j_sum objective, matching
        # parameters) — under --objective j_max the comparison is apples
        # to oranges, so skip the claim rather than report a false FAIL.
        if "refined" in variants and objective == "j_sum":
            ragged = [r for r in rows if r["ragged"]]
            worse = [r for r in ragged
                     if r[f"j_max_{variant}"] > r["j_max_refined"]]
            claims.append(("PASS" if not worse else "FAIL")
                          + f": {variant} J_max <= refined J_max on all "
                          f"{len(ragged)} ragged-pod rows"
                          + (f" (violations: {[(r['instance'], r['mapper']) for r in worse]})"
                             if worse else ""))
    return claims


_SHORT = {"refined": "ref", "refined2": "ref2", "annealed": "ann"}


def print_table(rows, variants=VARIANTS):
    short = [_SHORT.get(v, v[:4]) for v in variants]
    cols = "".join(f" {'Jsum_' + s:>9s} {'Jmax_' + s:>9s}" for s in short)
    times = "".join(f" {'t_' + s:>9s}" for s in short)
    print(f"{'instance':18s} {'stencil':8s} {'mapper':15s} "
          f"{'J_sum':>7s} {'J_max':>6s}{cols}{times}")
    for r in rows:
        v_cols = "".join(f" {r[f'j_sum_{v}']:9.0f} {r[f'j_max_{v}']:9.0f}"
                         for v in variants)
        v_times = "".join(f" {r[f't_{v}_s'] * 1e3:7.1f}ms" for v in variants)
        print(f"{r['instance']:18s} {r['stencil']:8s} {r['mapper']:15s} "
              f"{r['j_sum_base']:7.0f} {r['j_max_base']:6.0f}"
              f"{v_cols}{v_times}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="smoke subset")
    ap.add_argument("--mappers", default=None,
                    help="comma list (default: all registered)")
    ap.add_argument("--variants", default=",".join(VARIANTS),
                    help="comma list of refinement prefixes to compare")
    ap.add_argument("--policy", default="first",
                    choices=["first", "steepest"])
    ap.add_argument("--objective", default="j_sum",
                    choices=["j_sum", "j_max"],
                    help="refined: objective (scheduled variants own theirs)")
    ap.add_argument("--json", default=None, help="also dump rows as JSON")
    args = ap.parse_args()

    variants = tuple(args.variants.split(","))
    rows = run(tiny=args.tiny,
               mappers=args.mappers.split(",") if args.mappers else None,
               variants=variants,
               refine_kwargs={"policy": args.policy,
                              "objective": args.objective})
    print_table(rows, variants=variants)
    print()
    claims = validate_claims(rows, objective=args.objective,
                             variants=variants)
    for c in claims:
        print("# " + c)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=float)
    if any(c.startswith("FAIL") for c in claims):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
