"""Roofline + linksim table from the dry-run artifacts (runs/dryrun/*.json).

Rows: per (arch, shape, mesh): the three roofline terms, the dominant one,
MFU bound, and — the paper's metric on the production topology — inter-pod
DCI bytes under each mapping algorithm (multi-pod mesh only).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List


def run(dryrun_dir: str = "runs/dryrun") -> List[Dict]:
    rows = []
    d = Path(dryrun_dir)
    if not d.exists():
        return [{"name": "roofline_missing_dryrun", "us_per_call": 0,
                 "derived": 0}]
    for f in sorted(d.glob("*.json")):
        if f.name == "summary.json":
            continue
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        roof = r["roofline"]
        step = max(roof["t_compute_s"], roof["t_memory_s"],
                   roof["t_collective_s"])
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": step * 1e6,       # roofline step-time bound
            "derived": roof["mfu_bound"],
            "dominant": roof["dominant"],
            "useful_ratio": roof["useful_ratio"],
        })
        if r["mesh"] == "multi" and "linksim" in r:
            blocked = r["linksim"].get("blocked", {})
            for mname, rep in r["linksim"].items():
                if mname == "blocked":
                    continue
                base = blocked.get("dci_total_bytes", 0) or 1.0
                rows.append({
                    "name": f"dci_{r['arch']}_{r['shape']}_{mname}",
                    "us_per_call": rep.get("t_dci_bottleneck", 0) * 1e6,
                    "derived": rep.get("dci_total_bytes", 0) / base,
                })
    return rows
